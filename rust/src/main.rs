//! `ctaylor` — CLI for the collapsed-Taylor reproduction.
//!
//! Subcommands map 1:1 to the experiment index in DESIGN.md §4:
//!
//! ```text
//! ctaylor info                         # manifest + spec-preset overview
//! ctaylor gamma                        # fig. 4: interpolation coefficients
//! ctaylor spec [--op helmholtz] [--dim 16] [--c0 2.25] [--c2 1.0]
//! ctaylor analyze <name|path>...       # HLO memory/FLOP analysis
//! ctaylor eval --op laplacian --method collapsed [--n 8]
//!              [--train N [--opt sgd|adam] [--lr 1e-3]]   # pinn_steps, then eval trained θ
//! ctaylor bench [--which fig1|table1|f2|g3|native|graph|kernels|threads|smoke|coordinator|all]
//!               [--reps N]
//! ctaylor bench run --cell <id> [--json] [--warmup N] [--iters N]
//! ctaylor bench barometer [--matrix full|reduced] [--list] [--out FILE]
//!                         [--warmup N] [--iters N]
//! ctaylor bench cmp OLD.json NEW.json [--threshold PCT] [--fail-on-regress PCT]
//! ctaylor bench serve [--scenario all|baseline|fanout|fanin|scale|chaos|faults]
//!                     [--duration-ms N] [--shards N] [--seed N] [--json] [--out FILE]
//! ctaylor serve [--addr HOST:PORT] [--shards N] [--deadline-ms N] [--queue-capacity N]
//!               [--max-conns N] [--faults SPEC]    # SPEC: seed=N | panic@N;stall@N:2ms;drop@N
//! ctaylor serve-demo [--requests N]    # coordinator under load
//! ```

use anyhow::{bail, Context, Result};

use ctaylor::api::Engine;
use ctaylor::bench;
use ctaylor::bench::barometer;
use ctaylor::bench::serve;
use ctaylor::coordinator::{RouteKey, Service, ServiceConfig, TrainSpec};
use ctaylor::hlo;
use ctaylor::operators::interpolation::{compositions, gamma};
use ctaylor::operators::plan::{HELMHOLTZ_C0, HELMHOLTZ_C2};
use ctaylor::operators::OperatorSpec;
use ctaylor::runtime::{HostTensor, Registry};
use ctaylor::taylor::count;
use ctaylor::util::cli::Args;
use ctaylor::util::json;
use ctaylor::util::prng::Rng;
use ctaylor::util::stats::fmt_bytes;

fn main() -> Result<()> {
    let args = Args::from_env(&["verbose", "json", "list"]);
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("gamma") => cmd_gamma(),
        Some("spec") => cmd_spec(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("eval") => cmd_eval(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some(other) => bail!("unknown subcommand {other:?}; see `ctaylor help` in README"),
        None => {
            println!(
                "ctaylor — Collapsing Taylor Mode AD (NeurIPS 2025) reproduction\n\
                 subcommands: info | gamma | spec | analyze | eval | bench | serve | serve-demo"
            );
            Ok(())
        }
    }
}

/// Load the manifest named by `--artifacts` (default ./artifacts), falling
/// back to the builtin preset when none exists so every subcommand works
/// with zero setup.  A present-but-malformed manifest is a hard error.
fn registry(args: &Args) -> Result<Registry> {
    Registry::load_or_builtin(args.get_or("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = Engine::builder().registry(registry(args)?).build()?;
    let reg = engine.registry();
    println!("preset: {}  artifacts: {}", reg.preset, reg.artifacts.len());
    println!("engine: native-cpu  {}", engine.stats());
    let svc_defaults = ServiceConfig::default();
    println!(
        "serving: shards={} (default)  queue={}/shard  deadline={}ms  latency hist: 64 \
         √2-spaced buckets from 1µs",
        svc_defaults.resolved_shards(),
        svc_defaults.queue_capacity,
        svc_defaults.default_deadline.as_millis()
    );
    let mut by_op = std::collections::BTreeMap::new();
    for a in &reg.artifacts {
        *by_op.entry(format!("{}/{}/{}", a.op, a.method, a.mode)).or_insert(0) += 1;
    }
    for (k, v) in by_op {
        println!("  {k:<42} {v} artifacts");
    }
    let dim_of = |op: &str, fallback: usize| {
        reg.select(op, "collapsed", "exact").first().map(|a| a.dim).unwrap_or(fallback)
    };
    let lap_dim = dim_of("laplacian", 16);
    let bih_dim = dim_of("biharmonic", 4);
    println!("\nspec presets (operators::plan — one stacked jet push each):");
    for spec in [
        OperatorSpec::laplacian(lap_dim),
        OperatorSpec::helmholtz_preset(dim_of("helmholtz", lap_dim)),
        OperatorSpec::biharmonic(bih_dim),
    ] {
        print_spec(&spec);
    }
    Ok(())
}

fn print_spec(spec: &OperatorSpec) {
    let plan = spec.compile();
    let r = plan.dirs.shape[0];
    println!(
        "  {:<22} K={}  families={}  bundle R={}  vectors/node std={} col={}",
        format!("{} (D={})", spec.name, spec.dim().unwrap_or(0)),
        plan.order,
        spec.families.len(),
        r,
        count::vectors_standard(plan.order, r),
        count::vectors_collapsed(plan.order, r),
    );
}

fn cmd_gamma() -> Result<()> {
    println!("Interpolation coefficients γ_(2,2),j for the biharmonic (paper fig. 4):");
    for j in compositions(4, 2) {
        let g = gamma(&[2, 2], &j);
        println!("  j = ({}, {}):  γ = {}/{}", j[0], j[1], g.num, g.den);
    }
    let spec = OperatorSpec::biharmonic(4);
    let plan = spec.compile();
    println!("\nγ-derived biharmonic spec (D = 4):");
    for (fam, label) in spec.families.iter().zip(["A: 4e_d", "B: 3e_d1+e_d2", "C: 2e_d1+2e_d2"]) {
        println!("  family {label:<14} weight {:+.6}  ({} dirs)", fam.weight, fam.dirs.shape[0]);
    }
    println!(
        "compiled: one stacked bundle of {} directions — a single 4-jet push \
         (the pre-plan engine pushed each family separately)",
        plan.dirs.shape[0]
    );
    Ok(())
}

/// Print a composed OperatorSpec and its compiled single-bundle plan.
fn cmd_spec(args: &Args) -> Result<()> {
    let op = args.get_or("op", "helmholtz").to_string();
    let dim = args.get_usize("dim", 16);
    let spec = match op.as_str() {
        "laplacian" => OperatorSpec::laplacian(dim),
        "biharmonic" => OperatorSpec::biharmonic(dim),
        "helmholtz" => OperatorSpec::helmholtz(
            dim,
            args.get_f64("c0", HELMHOLTZ_C0),
            args.get_f64("c2", HELMHOLTZ_C2),
        ),
        other => bail!("unknown spec preset {other:?} (laplacian | biharmonic | helmholtz)"),
    };
    let plan = spec.compile();
    println!(
        "spec {}: c0={}  K={}  families={}",
        spec.name,
        spec.c0,
        plan.order,
        spec.families.len()
    );
    for f in &spec.families {
        println!("  degree {} × {:>3} dirs  weight {:+.6}", f.degree, f.dirs.shape[0], f.weight);
    }
    println!(
        "compiled: one bundle of {} directions ({} in the degree-K sum, {} lower-degree reads)",
        plan.dirs.shape[0],
        plan.num_top_dirs,
        plan.lower.len()
    );
    if plan.order >= 2 {
        println!(
            "vectors/node: standard {} vs collapsed {} (ratio {:.2})",
            count::vectors_standard(plan.order, plan.dirs.shape[0]),
            count::vectors_collapsed(plan.order, plan.dirs.shape[0]),
            count::vectors_collapsed(plan.order, plan.dirs.shape[0]) as f64
                / count::vectors_standard(plan.order, plan.dirs.shape[0]) as f64
        );
    }

    // Evaluate the composed spec through the Engine front door: an ad-hoc
    // spec compiles to a typed handle with the engine's default collapse
    // policy and runs on a deterministic Glorot network.
    let engine = Engine::builder().registry(registry(args)?).build()?;
    let handle = engine.compile_default(spec, &[32, 32, 1])?;
    let meta = handle.meta().clone();
    let mut rng = Rng::new(args.get_u64("seed", 42));
    let theta = meta.glorot_theta(&mut rng);
    let batch = 4usize;
    let mut xdata = vec![0.0f32; batch * dim];
    rng.fill_normal_f32(&mut xdata);
    let x = HostTensor::new(vec![batch, dim], xdata);
    let out = handle.eval().theta(&theta).x(&x).run()?;
    println!("\nengine.compile({}, {}) on a Glorot 32-32-1 net:", meta.name, handle.method());
    for b in 0..batch {
        println!("  f(x_{b}) = {:+.6}   L f(x_{b}) = {:+.6}", out.f0.data[b], out.op.data[b]);
    }
    println!("engine stats: {}", engine.stats());
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let reg = registry(args).ok();
    if args.positional.is_empty() {
        bail!("usage: ctaylor analyze <artifact-name|path> ...");
    }
    for target in &args.positional {
        let path = if std::path::Path::new(target).exists() {
            std::path::PathBuf::from(target)
        } else if let Some(reg) = &reg {
            let meta = reg
                .get(target)
                .with_context(|| format!("{target:?} is neither a file nor an artifact"))?;
            meta.hlo_path(&reg.dir)
        } else {
            bail!("{target:?} not found");
        };
        let an = hlo::analyze_file(&path)?;
        println!(
            "{target}: instrs={} params={} intermediates(diff)={} peak(non-diff)={} flops={}",
            an.instructions,
            fmt_bytes(an.parameter_bytes as f64),
            fmt_bytes(an.total_intermediate_bytes as f64),
            fmt_bytes(an.peak_live_bytes as f64),
            an.flops
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let reg = registry(args)?;
    let op = args.get_or("op", "laplacian").to_string();
    let method = args.get_or("method", "collapsed").to_string();
    let mode = args.get_or("mode", "exact").to_string();
    let dim = reg
        .select(&op, &method, &mode)
        .first()
        .map(|a| a.dim)
        .context("no artifacts for that route")?;
    let n = args.get_usize("n", 8);
    let seed = args.get_u64("seed", 42);
    let train_steps = args.get_usize("train", 0);

    let svc = Service::start(reg, ServiceConfig::default())?;
    let mut rng = Rng::new(seed);
    let mut pts = vec![0.0f32; n * dim];
    if train_steps > 0 {
        // Training collocation points live in the PINN domain [0,1]^D;
        // the forcing is the manufactured f = D·π²·∏ sin(πxᵢ) of
        // examples/pinn_poisson.rs, so --train N runs N pinn_steps
        // against the shard's resident θ before the eval below serves it.
        for p in pts.iter_mut() {
            *p = rng.uniform() as f32;
        }
        let pi = std::f32::consts::PI;
        let forcing: Vec<f32> = (0..n)
            .map(|row| {
                let prod: f32 =
                    pts[row * dim..(row + 1) * dim].iter().map(|&v| (pi * v).sin()).product();
                dim as f32 * pi * pi * prod
            })
            .collect();
        let spec = TrainSpec {
            forcing,
            steps: train_steps,
            lr: args.get_f64("lr", 1e-3),
            optimizer: args.get_or("opt", "adam").to_string(),
        };
        let out = svc.train_blocking(RouteKey::new(&op, &method, &mode), pts.clone(), dim, spec)?;
        println!(
            "trained {train_steps} pinn_step(s) on shard {}: interior loss {:.6e} -> {:.6e} \
             ({:.3}ms)",
            out.shard,
            out.losses.first().copied().unwrap_or(f32::NAN),
            out.losses.last().copied().unwrap_or(f32::NAN),
            out.latency_s * 1e3
        );
    } else {
        rng.fill_normal_f32(&mut pts);
    }
    let resp = svc.eval_blocking(RouteKey::new(&op, &method, &mode), pts, dim)?;
    println!("{op}/{method}/{mode}  D={dim}  n={n}  latency={:.3}ms", resp.latency_s * 1e3);
    for i in 0..n.min(8) {
        println!("  f(x_{i}) = {:+.6}   op(x_{i}) = {:+.6}", resp.f0[i], resp.op[i]);
    }
    svc.shutdown();
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    // Positional sub-subcommands are the barometer surface; the legacy
    // `--which` selector (paper tables, smoke bench) stays untouched.
    match args.positional.first().map(String::as_str) {
        Some("run") => return cmd_bench_run(args),
        Some("barometer") => return cmd_bench_barometer(args),
        Some("cmp") => return cmd_bench_cmp(args),
        Some("serve") => return cmd_bench_serve(args),
        Some(other) => bail!("unknown bench subcommand {other:?} (run | barometer | cmp | serve)"),
        None => {}
    }
    let which = args.get_or("which", "all").to_string();
    let reps = args.get_usize("reps", 10);
    let reg = registry(args)?;
    let run = |name: &str| which == "all" || which == name;
    if run("fig1") {
        println!("{}", bench::run_fig1(&reg, reps)?);
    }
    if run("table1") || which == "fig5" {
        println!("{}", bench::run_fig5_table1(&reg, reps)?);
    }
    if run("f2") {
        println!("{}", bench::run_table_f2(&reg, reps)?);
    }
    if run("g3") || which == "g9" {
        println!("{}", bench::run_figg9_tableg3(&reg, reps)?);
    }
    if run("native") {
        println!("{}", bench::run_native_ablation(reps.max(5))?);
    }
    if run("graph") {
        println!("{}", bench::run_graph_ablation(reps.max(5))?);
    }
    if run("kernels") {
        println!("{}", bench::run_kernel_micro(reps.max(3))?);
    }
    if run("threads") {
        println!("{}", bench::run_thread_scaling(&reg, reps.max(3))?);
    }
    if which == "smoke" {
        println!("{}", bench::run_smoke(&reg, reps)?);
    }
    if run("coordinator") {
        let reg2 = registry(args)?;
        println!("{}", bench::run_coordinator_bench(reg2, args.get_usize("requests", 200))?);
    }
    Ok(())
}

/// `bench run --cell <id>`: measure one barometer cell in this process
/// and print its record. With `--json` the record line is the only
/// output; the driver and CI parse the *last* stdout line either way.
fn cmd_bench_run(args: &Args) -> Result<()> {
    let id = args
        .get("cell")
        .context("usage: ctaylor bench run --cell <id> [--json] [--warmup N] [--iters N]")?;
    let mut cell = barometer::find_cell(id).with_context(|| {
        format!("unknown cell {id:?}; `ctaylor bench barometer --list` prints the matrix")
    })?;
    cell.warmup = args.get_usize("warmup", cell.warmup);
    cell.iters = args.get_usize("iters", cell.iters);
    let record = barometer::run_cell(&cell)?;
    if !args.flag("json") {
        println!("{}", barometer::describe_record(&record));
    }
    println!("{}", json::to_string(&record));
    Ok(())
}

/// `bench barometer`: spawn the binary once per matrix cell (process
/// isolation) and write the aggregated snapshot.
fn cmd_bench_barometer(args: &Args) -> Result<()> {
    let cells = match args.get_or("matrix", "full") {
        "full" => barometer::full_matrix(),
        "reduced" => barometer::reduced_matrix(),
        other => bail!("--matrix expects full or reduced, got {other:?}"),
    };
    if args.flag("list") {
        for c in &cells {
            println!("{}", c.id());
        }
        return Ok(());
    }
    let bin = std::env::current_exe().context("locating the ctaylor binary")?;
    let warmup = args.get("warmup").map(str::parse).transpose()?;
    let iters = args.get("iters").map(str::parse).transpose()?;
    let mut records = Vec::new();
    for (i, c) in cells.iter().enumerate() {
        let id = c.id();
        eprintln!("[{}/{}] {id}", i + 1, cells.len());
        records.push(barometer::spawn_cell(&bin, &id, warmup, iters)?);
    }
    let snap = barometer::snapshot(records);
    let out = args.get_or("out", "BENCH_barometer.json");
    std::fs::write(out, json::to_string(&snap) + "\n")
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {out} ({} cells)", cells.len());
    Ok(())
}

/// `bench serve`: the serving scenario suite.  `--scenario all` (the
/// default) spawns the release binary once per scenario — process
/// isolation, like the barometer — and prints one versioned JSON line
/// per scenario; a single `--scenario NAME` runs in-process with the
/// summary as the last stdout line.  Exits nonzero when any scenario
/// fails its correctness checks (oracle mismatch or untyped rejection).
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let opts = serve::ServeOpts {
        duration: std::time::Duration::from_millis(args.get_u64("duration-ms", 2000)),
        shards: args.get_usize("shards", 0),
        seed: args.get_u64("seed", 0xC0FFEE),
    };
    let scenario = args.get_or("scenario", "all").to_string();
    if scenario == "all" {
        let names: Vec<&str> = serve::SCENARIOS.to_vec();
        let (lines, ok) = serve::run_suite(
            &names,
            &opts,
            args.get_or("artifacts", "artifacts"),
            args.get("out"),
        )?;
        println!("{lines}");
        if !ok {
            bail!("serve suite failed (see scenario summaries above)");
        }
        return Ok(());
    }
    let reg = registry(args)?;
    if !args.flag("json") {
        println!("# serve scenario {scenario}: {}", serve::describe(&scenario));
    }
    let j = serve::run_scenario(&scenario, &reg, &opts)?;
    let ok = j.get("ok").and_then(|v| v.as_bool()) == Some(true);
    println!("{}", json::to_string(&j));
    if !ok {
        bail!("scenario {scenario} failed its correctness checks");
    }
    Ok(())
}

/// `bench cmp OLD.json NEW.json`: join two snapshots by cell id, print
/// the human report, then the single-line JSON summary as the last stdout
/// line. Exits nonzero when `--fail-on-regress` trips.
fn cmd_bench_cmp(args: &Args) -> Result<()> {
    if args.positional.len() != 3 {
        bail!("usage: ctaylor bench cmp OLD.json NEW.json [--threshold PCT] [--fail-on-regress PCT]");
    }
    let cfg = barometer::CmpConfig {
        threshold_pct: args.get_f64("threshold", 5.0),
        fail_on_regress_pct: args.get("fail-on-regress").map(str::parse).transpose()?,
    };
    let old = barometer::load_snapshot(&args.positional[1])?;
    let new = barometer::load_snapshot(&args.positional[2])?;
    let report = barometer::cmp_records(&old, &new, &cfg)?;
    print!("{}", report.render_text());
    println!("{}", json::to_string(&report.summary_json()));
    if report.failed {
        // Rust's stdout is line-buffered; the summary line above is
        // already flushed when we take the gating exit.
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::Arc;
    let reg = registry(args)?;
    let mut cfg = ServiceConfig {
        shards: args.get_usize("shards", 0),
        queue_capacity: args.get_usize("queue-capacity", 1024),
        default_deadline: std::time::Duration::from_millis(args.get_u64("deadline-ms", 5)),
        ..ServiceConfig::default()
    };
    if let Some(spec) = args.get("faults") {
        // Explicit flag beats the CTAYLOR_FAULTS env var (chaos drills).
        cfg.faults = Some(Arc::new(ctaylor::coordinator::FaultPlan::parse(spec)?));
    }
    let svc = Arc::new(Service::start(reg, cfg)?);
    let addr = args.get_or("addr", "127.0.0.1:8042");
    let server_cfg = ctaylor::coordinator::ServerConfig {
        max_connections: args.get_usize("max-conns", 256),
        ..Default::default()
    };
    let server = ctaylor::coordinator::Server::start_with(svc.clone(), addr, server_cfg)?;
    println!(
        "serving PDE operators on {} ({} shards, JSON lines; ctrl-c to stop)",
        server.addr(),
        svc.shards()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!("{}", svc.metrics().summary());
    }
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let reg = registry(args)?;
    let n = args.get_usize("requests", 100);
    println!("{}", bench::run_coordinator_bench(reg, n)?);
    Ok(())
}
