//! Sweeps: measure one (op, method, mode) family across its compiled
//! batch/sample ladder and fit per-datum / per-sample slopes — the paper's
//! benchmarking protocol (min of N reps, linear fits; §4 and table 1).

use anyhow::{bail, Result};

use crate::api::Engine;
use crate::hlo;
use crate::mlp::Mlp;
use crate::operators::OperatorSpec;
use crate::runtime::ArtifactMeta;
use crate::taylor::count;
use crate::taylor::hlo_emit;
use crate::taylor::jet::Collapse;
use crate::taylor::rewrite;
use crate::taylor::tensor::Tensor;
use crate::taylor::trace;
use crate::util::prng::Rng;
use crate::util::stats::{linear_fit, time_fn, LinearFit};

use super::workload;

/// Where one point's memory/FLOP proxies come from: real on-disk HLO
/// text, HLO emitted from the route's traced (+collapsed) graph, or the
/// analytic count-model fallback.
pub const MEM_HLO: &str = "hlo";
pub const MEM_GRAPH_HLO: &str = "graph-hlo";
pub const MEM_COUNT_MODEL: &str = "count-model";

/// One measured point of a sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    /// Batch size (exact) or sample count (stochastic).
    pub x: f64,
    /// Min runtime over reps (seconds).
    pub time_s: f64,
    /// Differentiable-memory proxy (bytes).
    pub mem_diff: f64,
    /// Non-differentiable-memory proxy (bytes).
    pub mem_nondiff: f64,
    /// Estimated FLOPs.
    pub flops: f64,
    /// Provenance of the memory/FLOP numbers ([`MEM_HLO`],
    /// [`MEM_GRAPH_HLO`] or [`MEM_COUNT_MODEL`]).
    pub mem_source: &'static str,
}

/// A measured family with its fitted slopes.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub op: String,
    pub method: String,
    pub mode: String,
    pub points: Vec<SweepPoint>,
    pub time_fit: LinearFit,
    pub mem_diff_fit: LinearFit,
    pub mem_nondiff_fit: LinearFit,
}

impl Sweep {
    /// ms added per datum/sample (the paper's headline quantity).
    pub fn ms_per_x(&self) -> f64 {
        self.time_fit.slope * 1e3
    }

    /// Worst provenance across the family's points: "hlo" when every
    /// point analyzed real HLO text, "graph-hlo" when the weakest source
    /// was emitted-graph analysis, "count-model" when any point fell back
    /// to the analytic model.
    pub fn mem_source(&self) -> &'static str {
        if self.points.iter().any(|p| p.mem_source == MEM_COUNT_MODEL) {
            MEM_COUNT_MODEL
        } else if self.points.iter().any(|p| p.mem_source == MEM_GRAPH_HLO) {
            MEM_GRAPH_HLO
        } else {
            MEM_HLO
        }
    }

    /// MiB added per datum/sample.
    pub fn mib_diff_per_x(&self) -> f64 {
        self.mem_diff_fit.slope / (1024.0 * 1024.0)
    }

    pub fn mib_nondiff_per_x(&self) -> f64 {
        self.mem_nondiff_fit.slope / (1024.0 * 1024.0)
    }
}

/// A representative `OperatorSpec` for one route, used only for graph
/// shape/structure (σ is the identity, stochastic directions are dummy
/// unit rows — memory/FLOP proxies depend on R and K, not on values).
fn spec_for_proxy(meta: &ArtifactMeta) -> Option<OperatorSpec> {
    use crate::operators::plan::{HELMHOLTZ_C0, HELMHOLTZ_C2};
    let d = meta.dim;
    if meta.mode == "stochastic" {
        if meta.samples == 0 {
            return None;
        }
        let dirs = Tensor::new(vec![meta.samples, d], vec![1.0; meta.samples * d]);
        return match meta.op.as_str() {
            "laplacian" | "weighted_laplacian" => Some(OperatorSpec::stochastic_laplacian(&dirs)),
            "helmholtz" => {
                Some(OperatorSpec::stochastic_helmholtz(HELMHOLTZ_C0, HELMHOLTZ_C2, &dirs))
            }
            "biharmonic" => Some(OperatorSpec::stochastic_biharmonic(&dirs)),
            _ => None,
        };
    }
    match meta.op.as_str() {
        "laplacian" => Some(OperatorSpec::laplacian(d)),
        "weighted_laplacian" => {
            Some(OperatorSpec::weighted_laplacian(&crate::operators::basis(d)))
        }
        "helmholtz" => Some(OperatorSpec::helmholtz_preset(d)),
        "biharmonic" => Some(OperatorSpec::biharmonic(d)),
        _ => None,
    }
}

/// Graph-derived HLO proxies for builtin Taylor-method artifacts: trace
/// the route's plan, run the §C rewrites for the collapsed method, emit
/// HLO text and push it through the real `hlo::analyzer` — the same
/// analysis AOT artifacts get, instead of the count-model fallback.
fn graph_proxy(meta: &ArtifactMeta) -> Option<(f64, f64, f64)> {
    let mode = match meta.method.as_str() {
        "standard" => Collapse::Standard,
        "collapsed" => Collapse::Collapsed,
        _ => return None, // nested AD has no Taylor graph
    };
    let spec = spec_for_proxy(meta)?;
    let plan = spec.compile();
    if plan.order == 0 || plan.dirs.shape[0] == 0 {
        return None;
    }
    let batch = meta.batch.max(1);
    // Weight values don't affect the proxies; a deterministic init keeps
    // the traced constants well-formed.
    let mlp = Mlp::init(&mut Rng::new(0), meta.dim, &meta.widths, batch);
    let g = trace::build_plan_jet_std(&mlp, &plan, batch);
    let g = match mode {
        Collapse::Collapsed => rewrite::collapse(&g, trace::TAGGED_SLOTS, plan.dirs.shape[0]),
        Collapse::Standard => g,
    };
    let shapes = vec![vec![batch, meta.dim], vec![plan.dirs.shape[0], batch, meta.dim]];
    let text = hlo_emit::emit(&g, &shapes, &meta.name).ok()?;
    let module = hlo::parser::parse_module(&text).ok()?;
    let a = hlo::analyzer::analyze(&module).ok()?;
    Some((a.total_intermediate_bytes as f64, a.peak_live_bytes as f64, a.flops as f64))
}

/// Analytic stand-in for the HLO proxies when an artifact ships no HLO
/// text (the builtin preset): the paper's propagated-vector cost model
/// (`taylor::count::route_proxy`) times the network's activation
/// footprint — the same model the barometer records, so sweep tables and
/// barometer cells report identical proxies for identical routes.
fn analytic_proxy(meta: &ArtifactMeta) -> (f64, f64, f64) {
    let p = count::route_proxy(
        &meta.op,
        &meta.method,
        &meta.mode,
        meta.dim,
        meta.samples,
        count::NetShape { batch: meta.batch, widths: &meta.widths, theta_len: meta.theta_len },
    );
    (p.mem_diff_bytes, p.mem_nondiff_bytes, p.flops)
}

/// Measure one family through the public `Engine` surface.  `reps` timed
/// repetitions per artifact (min kept).
pub fn run_sweep(
    engine: &Engine,
    op: &str,
    method: &str,
    mode: &str,
    reps: usize,
    seed: u64,
) -> Result<Sweep> {
    let registry = engine.registry();
    let artifacts = registry.select(op, method, mode);
    if artifacts.len() < 2 {
        bail!("need >= 2 artifacts for a sweep of {op}/{method}/{mode}");
    }
    let mut points = Vec::new();
    for meta in &artifacts {
        let handle = engine.operator(&meta.name)?;
        // Build the named inputs once; request construction borrows them,
        // so the timed region is validation + execution only.
        let w = workload::workload_for(meta, seed);
        let timing = time_fn(
            || {
                w.request(&handle).run().expect("bench execution failed");
            },
            reps,
        );
        // Memory/FLOP proxies come from the artifact's HLO text when it
        // exists; builtin (fileless) Taylor artifacts analyze HLO emitted
        // from their traced (+collapsed) graph; only routes without a
        // Taylor graph (nested AD) fall back to the propagated-vector
        // count model.
        let hlo_path = meta.hlo_path(&registry.dir);
        let (mem_diff, mem_nondiff, flops, mem_source) = if hlo_path.exists() {
            let a = hlo::analyze_file(&hlo_path)?;
            (
                a.total_intermediate_bytes as f64,
                a.peak_live_bytes as f64,
                a.flops as f64,
                MEM_HLO,
            )
        } else if let Some((d, nd, fl)) = graph_proxy(meta) {
            (d, nd, fl, MEM_GRAPH_HLO)
        } else {
            let (d, nd, fl) = analytic_proxy(meta);
            (d, nd, fl, MEM_COUNT_MODEL)
        };
        let x = if mode == "stochastic" { meta.samples } else { meta.batch };
        points.push(SweepPoint {
            x: x as f64,
            time_s: timing.min,
            mem_diff,
            mem_nondiff,
            flops,
            mem_source,
        });
    }
    let xs: Vec<f64> = points.iter().map(|p| p.x).collect();
    let t: Vec<f64> = points.iter().map(|p| p.time_s).collect();
    let md: Vec<f64> = points.iter().map(|p| p.mem_diff).collect();
    let mn: Vec<f64> = points.iter().map(|p| p.mem_nondiff).collect();
    Ok(Sweep {
        op: op.into(),
        method: method.into(),
        mode: mode.into(),
        time_fit: linear_fit(&xs, &t),
        mem_diff_fit: linear_fit(&xs, &md),
        mem_nondiff_fit: linear_fit(&xs, &mn),
        points,
    })
}
