//! Sweeps: measure one (op, method, mode) family across its compiled
//! batch/sample ladder and fit per-datum / per-sample slopes — the paper's
//! benchmarking protocol (min of N reps, linear fits; §4 and table 1).

use anyhow::{bail, Result};

use crate::hlo;
use crate::runtime::{ArtifactMeta, DeviceBuffer, Registry, RuntimeClient};
use crate::taylor::count;
use crate::util::stats::{linear_fit, time_fn, LinearFit};

use super::workload;

/// One measured point of a sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    /// Batch size (exact) or sample count (stochastic).
    pub x: f64,
    /// Min runtime over reps (seconds).
    pub time_s: f64,
    /// Differentiable-memory proxy (bytes).
    pub mem_diff: f64,
    /// Non-differentiable-memory proxy (bytes).
    pub mem_nondiff: f64,
    /// Estimated FLOPs.
    pub flops: f64,
    /// True when the memory/FLOP numbers come from real HLO analysis;
    /// false when they are the count-model fallback (builtin artifacts).
    pub mem_measured: bool,
}

/// A measured family with its fitted slopes.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub op: String,
    pub method: String,
    pub mode: String,
    pub points: Vec<SweepPoint>,
    pub time_fit: LinearFit,
    pub mem_diff_fit: LinearFit,
    pub mem_nondiff_fit: LinearFit,
}

impl Sweep {
    /// ms added per datum/sample (the paper's headline quantity).
    pub fn ms_per_x(&self) -> f64 {
        self.time_fit.slope * 1e3
    }

    /// "hlo" when every point's memory numbers come from HLO analysis,
    /// "count-model" when any point used the analytic fallback.
    pub fn mem_source(&self) -> &'static str {
        if self.points.iter().all(|p| p.mem_measured) {
            "hlo"
        } else {
            "count-model"
        }
    }

    /// MiB added per datum/sample.
    pub fn mib_diff_per_x(&self) -> f64 {
        self.mem_diff_fit.slope / (1024.0 * 1024.0)
    }

    pub fn mib_nondiff_per_x(&self) -> f64 {
        self.mem_nondiff_fit.slope / (1024.0 * 1024.0)
    }
}

/// Analytic stand-in for the HLO proxies when an artifact ships no HLO
/// text (the builtin preset): the paper's propagated-vector cost model
/// (`taylor::count::route_vectors`) times the network's activation
/// footprint.  Slope *ratios* between methods — the claims the tables
/// test — match the table-F2 Δ-vector theory by construction; absolute
/// bytes/FLOPs are a model, not a measurement.
fn analytic_proxy(meta: &ArtifactMeta) -> (f64, f64, f64) {
    let vecs =
        count::route_vectors(&meta.op, &meta.method, &meta.mode, meta.dim, meta.samples) as f64;
    let batch = meta.batch.max(1) as f64;
    let widths_sum: usize = meta.widths.iter().sum();
    let max_width = meta.widths.iter().copied().max().unwrap_or(1);
    let bytes = 4.0; // f32 activations
    let mem_diff = vecs * batch * widths_sum as f64 * bytes;
    let mem_nondiff = vecs * batch * 2.0 * max_width as f64 * bytes; // two live layers
    let flops = vecs * batch * 2.0 * meta.theta_len as f64;
    (mem_diff, mem_nondiff, flops)
}

/// Measure one family.  `reps` timed repetitions per artifact (min kept).
pub fn run_sweep(
    client: &RuntimeClient,
    registry: &Registry,
    op: &str,
    method: &str,
    mode: &str,
    reps: usize,
    seed: u64,
) -> Result<Sweep> {
    let artifacts = registry.select(op, method, mode);
    if artifacts.len() < 2 {
        bail!("need >= 2 artifacts for a sweep of {op}/{method}/{mode}");
    }
    let mut points = Vec::new();
    for meta in &artifacts {
        let model = client.load(registry, &meta.name)?;
        let inputs = workload::inputs_for(meta, seed);
        // Stage everything once; time pure execution.
        let bufs: Vec<DeviceBuffer> =
            inputs.iter().map(|t| model.stage(t)).collect::<Result<_>>()?;
        let refs: Vec<&DeviceBuffer> = bufs.iter().collect();
        let timing = time_fn(
            || {
                model.run_buffers(&refs).expect("bench execution failed");
            },
            reps,
        );
        // Memory/FLOP proxies come from the artifact's HLO text when it
        // exists; builtin (fileless) artifacts fall back to the paper's
        // propagated-vector cost model instead of reporting zero.
        let hlo_path = meta.hlo_path(&registry.dir);
        let mem_measured = hlo_path.exists();
        let (mem_diff, mem_nondiff, flops) = if mem_measured {
            let a = hlo::analyze_file(&hlo_path)?;
            (a.total_intermediate_bytes as f64, a.peak_live_bytes as f64, a.flops as f64)
        } else {
            analytic_proxy(meta)
        };
        let x = if mode == "stochastic" { meta.samples } else { meta.batch };
        points.push(SweepPoint {
            x: x as f64,
            time_s: timing.min,
            mem_diff,
            mem_nondiff,
            flops,
            mem_measured,
        });
    }
    let xs: Vec<f64> = points.iter().map(|p| p.x).collect();
    let t: Vec<f64> = points.iter().map(|p| p.time_s).collect();
    let md: Vec<f64> = points.iter().map(|p| p.mem_diff).collect();
    let mn: Vec<f64> = points.iter().map(|p| p.mem_nondiff).collect();
    Ok(Sweep {
        op: op.into(),
        method: method.into(),
        mode: mode.into(),
        time_fit: linear_fit(&xs, &t),
        mem_diff_fit: linear_fit(&xs, &md),
        mem_nondiff_fit: linear_fit(&xs, &mn),
        points,
    })
}
