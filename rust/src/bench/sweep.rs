//! Sweeps: measure one (op, method, mode) family across its compiled
//! batch/sample ladder and fit per-datum / per-sample slopes — the paper's
//! benchmarking protocol (min of N reps, linear fits; §4 and table 1).

use anyhow::{bail, Result};

use crate::hlo;
use crate::runtime::{DeviceBuffer, Registry, RuntimeClient};
use crate::util::stats::{linear_fit, time_fn, LinearFit};

use super::workload;

/// One measured point of a sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    /// Batch size (exact) or sample count (stochastic).
    pub x: f64,
    /// Min runtime over reps (seconds).
    pub time_s: f64,
    /// Differentiable-memory proxy (bytes, from HLO analysis).
    pub mem_diff: f64,
    /// Non-differentiable-memory proxy (bytes).
    pub mem_nondiff: f64,
    /// Estimated FLOPs.
    pub flops: f64,
}

/// A measured family with its fitted slopes.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub op: String,
    pub method: String,
    pub mode: String,
    pub points: Vec<SweepPoint>,
    pub time_fit: LinearFit,
    pub mem_diff_fit: LinearFit,
    pub mem_nondiff_fit: LinearFit,
}

impl Sweep {
    /// ms added per datum/sample (the paper's headline quantity).
    pub fn ms_per_x(&self) -> f64 {
        self.time_fit.slope * 1e3
    }

    /// MiB added per datum/sample.
    pub fn mib_diff_per_x(&self) -> f64 {
        self.mem_diff_fit.slope / (1024.0 * 1024.0)
    }

    pub fn mib_nondiff_per_x(&self) -> f64 {
        self.mem_nondiff_fit.slope / (1024.0 * 1024.0)
    }
}

/// Measure one family.  `reps` timed repetitions per artifact (min kept).
pub fn run_sweep(
    client: &RuntimeClient,
    registry: &Registry,
    op: &str,
    method: &str,
    mode: &str,
    reps: usize,
    seed: u64,
) -> Result<Sweep> {
    let artifacts = registry.select(op, method, mode);
    if artifacts.len() < 2 {
        bail!("need >= 2 artifacts for a sweep of {op}/{method}/{mode}");
    }
    let mut points = Vec::new();
    for meta in &artifacts {
        let model = client.load(registry, &meta.name)?;
        let inputs = workload::inputs_for(meta, seed);
        // Stage everything once; time pure execution.
        let bufs: Vec<DeviceBuffer> =
            inputs.iter().map(|t| model.stage(t)).collect::<Result<_>>()?;
        let refs: Vec<&DeviceBuffer> = bufs.iter().collect();
        let timing = time_fn(
            || {
                model.run_buffers(&refs).expect("bench execution failed");
            },
            reps,
        );
        // Memory/FLOP proxies come from the artifact's HLO text; builtin
        // (fileless) artifacts report zero until an AOT set is dropped in.
        let hlo_path = meta.hlo_path(&registry.dir);
        let an = if hlo_path.exists() { Some(hlo::analyze_file(&hlo_path)?) } else { None };
        let x = if mode == "stochastic" { meta.samples } else { meta.batch };
        points.push(SweepPoint {
            x: x as f64,
            time_s: timing.min,
            mem_diff: an.map(|a| a.total_intermediate_bytes as f64).unwrap_or(0.0),
            mem_nondiff: an.map(|a| a.peak_live_bytes as f64).unwrap_or(0.0),
            flops: an.map(|a| a.flops as f64).unwrap_or(0.0),
        });
    }
    let xs: Vec<f64> = points.iter().map(|p| p.x).collect();
    let t: Vec<f64> = points.iter().map(|p| p.time_s).collect();
    let md: Vec<f64> = points.iter().map(|p| p.mem_diff).collect();
    let mn: Vec<f64> = points.iter().map(|p| p.mem_nondiff).collect();
    Ok(Sweep {
        op: op.into(),
        method: method.into(),
        mode: mode.into(),
        time_fit: linear_fit(&xs, &t),
        mem_diff_fit: linear_fit(&xs, &md),
        mem_nondiff_fit: linear_fit(&xs, &mn),
        points,
    })
}
