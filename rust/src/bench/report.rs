//! Report rendering: aligned text tables (paper-style) + JSON dumps.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Render an aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string() + "\n"
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push_str(&fmt_row(
        &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
        &widths,
    ));
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// `0.33 (0.54x)` formatting used throughout the paper's tables.
pub fn with_ratio(value: f64, baseline: f64) -> String {
    if baseline == 0.0 {
        return format!("{value:.2}");
    }
    format!("{:.3} ({:.2}x)", value, value / baseline)
}

/// Persist a report section as JSON under `bench_results/`.
pub fn save_json(dir: &Path, name: &str, value: &Json) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json::to_string(value))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Read and parse a JSON file (the inverse of [`save_json`]); used by the
/// smoke bench to merge section reports and by the barometer to load
/// snapshots.
pub fn load_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Persist a rendered text section alongside the JSON.
pub fn save_text(dir: &Path, name: &str, text: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), text)?;
    Ok(())
}

/// Build a Json object from (key, f64) pairs.
pub fn jobj(pairs: &[(&str, f64)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(*v)))
            .collect::<BTreeMap<_, _>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = table(
            &["metric", "value"],
            &[
                vec!["time".into(), "0.33".into()],
                vec!["memory (MiB)".into(), "1.5".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("metric"));
        assert!(lines[2].len() == lines[3].len());
    }

    #[test]
    fn ratio_format_matches_paper_style() {
        assert_eq!(with_ratio(0.33, 0.61), "0.330 (0.54x)");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ctaylor-report-roundtrip");
        let v = jobj(&[("a", 1.0), ("b", 2.5)]);
        save_json(&dir, "roundtrip", &v).unwrap();
        assert_eq!(load_json(&dir.join("roundtrip.json")).unwrap(), v);
    }
}
