//! The performance barometer: a curated, process-isolated benchmark matrix
//! with a stable machine-readable record format and regression diffing.
//!
//! Modeled on rebar's METHODOLOGY: a small set of *curated* cells — not an
//! exhaustive sweep — each pinned to a fixed operator, engine, network
//! shape, batch and seed, so the same cell id always measures the same
//! computation. The driver (`ctaylor bench barometer`) spawns the release
//! binary once per cell (`ctaylor bench run --cell <id> --json`), which
//! isolates allocator state, caches and JIT-warmed code paths between
//! cells; within a process the cell runs `warmup` untimed iterations and
//! then `iters` timed ones, and reports the median (with min/max and
//! sample count) of the per-iteration wall-clock nanoseconds.
//!
//! # Cell ids
//!
//! A cell id encodes every knob of the measured computation:
//!
//! ```text
//! <op>-d<dim>-w<w0>x<w1>x…-b<batch>[-s<samples>]-<engine>
//! gemm-<m>x<k>x<n>-<ref|tiled>
//! ```
//!
//! e.g. `laplacian-d16-w32x32x1-b8-vm-col` or
//! `stochastic_laplacian-d16-w32x32x1-b4-s16-jet-col`. Engine tags:
//! `nested` (first-order AD composed K times), `jet-std` / `jet-col`
//! (the Taylor jet engine, standard vs collapsed propagation),
//! `interp-col` (graph interpreter on the §C-collapsed trace), `vm-std` /
//! `vm-col` (the buffer-planned VM on the standard vs collapsed trace),
//! `vm-col-f32` (the same collapsed program cast to f32 storage),
//! `grad` / `grad-f32` (one training step: the reverse-over-collapsed-
//! forward θ-gradient through the cached forward+backward pair, in f64
//! and f32 — see docs/training.md) and
//! `ref` / `tiled` / `tiled-f32` for the raw GEMM kernels.  f32 cells
//! carry distinct ids from their f64 counterparts, so a `cmp` join never
//! compares across precisions.
//!
//! # Record format (`ctaylor-barometer/1`)
//!
//! `ctaylor bench run --cell <id> --json` prints exactly one line: a JSON
//! object with these fields (this is the per-cell record that snapshot
//! files embed, and the format `ctaylor bench cmp` consumes):
//!
//! | field | meaning |
//! |---|---|
//! | `format` | the literal `"ctaylor-barometer/1"` |
//! | `id` | the cell id (join key for `cmp`) |
//! | `engine` | engine tag (redundant with the id, kept for filtering) |
//! | `op` | operator name, `gemm` for kernel cells |
//! | `dim` | input dimension D (0 for kernel cells) |
//! | `widths` | MLP layer widths, or `[m, k, n]` for kernel cells |
//! | `batch` | batch size B (0 for kernel cells) |
//! | `samples` | stochastic sample count S (0 = exact route) |
//! | `seed` | the PRNG seed, derived from the id (FNV-1a, masked to 31 bits) |
//! | `warmup` | untimed iterations run before measuring |
//! | `iters` | timed iterations |
//! | `git_rev` | `GITHUB_SHA`, else `git rev-parse --short HEAD`, else `unknown` |
//! | `wall_ns` | `{median, min, max, count}` over the timed iterations, in ns |
//! | `proxies` | `{vectors, flops, mem_diff_bytes, mem_nondiff_bytes}` from the `count` model |
//! | `env` | `{os, arch, threads, host}` fingerprint of the measuring machine |
//!
//! A snapshot file (`BENCH_barometer.json`) wraps the records:
//! `{format, git_rev, created_unix, env, cells: [record, …]}`.
//!
//! # Comparing snapshots
//!
//! [`cmp_records`] joins two snapshots by cell `id` and reads exactly one
//! number per cell: `wall_ns.median`. Cells whose median moved by more
//! than the noise threshold classify as regressions (slower) or
//! improvements (faster); ids present on only one side report as `added`
//! or `retired` rather than failing the join, which is what lets the
//! matrix evolve without breaking diffability. With a fail threshold set,
//! the report's `failed` flag trips when any *regressed* cell slowed by at
//! least that percentage.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::api::{Engine, Method, Precision};
use crate::mlp::Mlp;
use crate::nested;
use crate::operators::{self, plan, OperatorSpec};
use crate::operators::plan::OperatorPlan;
use crate::runtime::{HostTensor, Registry};
use crate::taylor::jet::Collapse;
use crate::taylor::kernels;
use crate::taylor::rewrite;
use crate::taylor::tensor::Tensor;
use crate::taylor::trace::{build_plan_jet_std, TAGGED_SLOTS};
use crate::taylor::{count, interp, program};
use crate::util::json::{self, Json};
use crate::util::prng::Rng;

use super::report::table;

/// Version tag every record and snapshot carries; bump on any breaking
/// change to the record format.
pub const FORMAT: &str = "ctaylor-barometer/1";

/// Version tag of the one-line `cmp` summary JSON.
pub const CMP_FORMAT: &str = "ctaylor-barometer-cmp/1";

/// Engines a matrix cell can run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// First-order AD nested K times (the paper's baseline).
    Nested,
    /// Taylor jet engine, standard propagation (1 + KR vectors).
    JetStd,
    /// Taylor jet engine, collapsed propagation (1 + (K-1)R + 1 vectors).
    JetCol,
    /// Reference graph interpreter on the §C-collapsed trace.
    InterpCol,
    /// Buffer-planned VM on the standard trace.
    VmStd,
    /// Buffer-planned VM on the §C-collapsed trace.
    VmCol,
    /// The collapsed VM program cast to f32 storage (`Precision::F32`).
    VmColF32,
    /// One training step: reverse-over-collapsed-forward θ-gradient
    /// through the cached forward+backward pair (`residual_grad`); the
    /// steady state measured here is VM execution only, compile excluded.
    /// Proxies report the forward collapsed pass — the adjoint roughly
    /// doubles the work, which is exactly what the cell measures.
    Grad,
    /// The same training step on the f32 engine (`Precision::F32`).
    GradF32,
    /// Naive triple-loop GEMM kernel (kernel cells only).
    GemmRef,
    /// Tiled packed GEMM kernel (kernel cells only).
    Gemm,
    /// Tiled packed GEMM kernel in f32 (kernel cells only).
    GemmF32,
}

impl EngineKind {
    /// The id suffix / `engine` record field.
    pub fn tag(self) -> &'static str {
        match self {
            EngineKind::Nested => "nested",
            EngineKind::JetStd => "jet-std",
            EngineKind::JetCol => "jet-col",
            EngineKind::InterpCol => "interp-col",
            EngineKind::VmStd => "vm-std",
            EngineKind::VmCol => "vm-col",
            EngineKind::VmColF32 => "vm-col-f32",
            EngineKind::Grad => "grad",
            EngineKind::GradF32 => "grad-f32",
            EngineKind::GemmRef => "ref",
            EngineKind::Gemm => "tiled",
            EngineKind::GemmF32 => "tiled-f32",
        }
    }

    /// The `count` cost-model method this engine propagates with.
    pub fn method(self) -> &'static str {
        match self {
            EngineKind::Nested => "nested",
            EngineKind::JetStd | EngineKind::VmStd => "standard",
            EngineKind::JetCol | EngineKind::InterpCol => "collapsed",
            EngineKind::VmCol | EngineKind::VmColF32 => "collapsed",
            EngineKind::Grad | EngineKind::GradF32 => "collapsed",
            EngineKind::GemmRef | EngineKind::Gemm | EngineKind::GemmF32 => "kernel",
        }
    }
}

/// One cell of the matrix: a fully pinned (operator × engine × network ×
/// batch × samples) measurement with its warmup/iteration budget.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Operator: `laplacian`, `weighted_laplacian`, `helmholtz`,
    /// `biharmonic`, `stochastic_laplacian`, `stochastic_biharmonic`,
    /// or `gemm` for kernel cells.
    pub op: &'static str,
    pub engine: EngineKind,
    /// Input dimension D; 0 for kernel cells.
    pub dim: usize,
    /// MLP layer widths; `[m, k, n]` for kernel cells.
    pub widths: Vec<usize>,
    /// Batch size; 0 for kernel cells.
    pub batch: usize,
    /// Stochastic sample count; 0 on exact routes.
    pub samples: usize,
    /// Untimed iterations before measurement.
    pub warmup: usize,
    /// Timed iterations (median reported).
    pub iters: usize,
    /// Whether the cell is part of the reduced (CI) matrix.
    pub reduced: bool,
}

impl Cell {
    fn exact(op: &'static str, engine: EngineKind, dim: usize, widths: &[usize], batch: usize) -> Cell {
        Cell {
            op,
            engine,
            dim,
            widths: widths.to_vec(),
            batch,
            samples: 0,
            warmup: 3,
            iters: 20,
            reduced: false,
        }
    }

    fn stochastic(
        op: &'static str,
        engine: EngineKind,
        dim: usize,
        widths: &[usize],
        batch: usize,
        samples: usize,
    ) -> Cell {
        Cell { samples, ..Cell::exact(op, engine, dim, widths, batch) }
    }

    fn gemm(engine: EngineKind, m: usize, k: usize, n: usize) -> Cell {
        Cell { dim: 0, batch: 0, ..Cell::exact("gemm", engine, 0, &[m, k, n], 0) }
    }

    fn reduced(mut self) -> Cell {
        self.reduced = true;
        self
    }

    /// Heavier cells (nested biharmonic, big GEMMs) get a smaller budget.
    fn heavy(mut self) -> Cell {
        self.warmup = 1;
        self.iters = 7;
        self
    }

    /// The stable cell id — the join key of the record format.
    pub fn id(&self) -> String {
        let w = self
            .widths
            .iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join("x");
        if self.op == "gemm" {
            return format!("gemm-{w}-{}", self.engine.tag());
        }
        let s = if self.samples > 0 { format!("-s{}", self.samples) } else { String::new() };
        format!("{}-d{}-w{w}-b{}{s}-{}", self.op, self.dim, self.batch, self.engine.tag())
    }
}

/// MLP widths of the fig1 configuration (D = 16 operators).
const W_MLP: &[usize] = &[32, 32, 1];
/// MLP widths of the biharmonic configuration (small D, quartic cost).
const W_BIH: &[usize] = &[16, 16, 1];
/// A deeper network, so depth scaling stays on the trajectory.
const W_DEEP: &[usize] = &[64, 64, 64, 1];

/// The full curated matrix. Order is presentation order; ids are the
/// identity. Adding a cell is backwards-compatible (it reports as `added`
/// in a cmp against an older snapshot); changing any knob of an existing
/// cell requires retiring its id and adding a new one.
pub fn full_matrix() -> Vec<Cell> {
    use EngineKind::*;
    let mut m = Vec::new();
    // Exact Laplacian on the fig1 config: every engine at B = 8, the
    // trajectory headliners again at B = 32.
    for e in [Nested, JetStd, JetCol, InterpCol, VmStd, VmCol] {
        let cell = Cell::exact("laplacian", e, 16, W_MLP, 8);
        m.push(if matches!(e, Nested | JetCol | VmCol) { cell.reduced() } else { cell });
    }
    for e in [Nested, JetCol, VmCol] {
        m.push(Cell::exact("laplacian", e, 16, W_MLP, 32));
    }
    // Weighted Laplacian and Helmholtz: the composed-spec routes.
    m.push(Cell::exact("weighted_laplacian", JetCol, 16, W_MLP, 8));
    m.push(Cell::exact("weighted_laplacian", VmCol, 16, W_MLP, 8).reduced());
    m.push(Cell::exact("helmholtz", JetCol, 16, W_MLP, 8));
    m.push(Cell::exact("helmholtz", VmStd, 16, W_MLP, 8));
    m.push(Cell::exact("helmholtz", VmCol, 16, W_MLP, 8).reduced());
    // Exact biharmonic (K = 4): the paper's strongest collapse claim.
    for e in [Nested, JetStd, JetCol, VmStd, VmCol] {
        let cell = Cell::exact("biharmonic", e, 4, W_BIH, 4);
        m.push(if e == Nested { cell.heavy() } else { cell });
    }
    m.push(Cell::exact("biharmonic", Nested, 4, W_BIH, 8).heavy().reduced());
    m.push(Cell::exact("biharmonic", VmCol, 4, W_BIH, 8).reduced());
    // Stochastic routes (STDE-style Monte-Carlo estimators).
    for s in [16, 64] {
        for e in [JetStd, JetCol, VmCol] {
            let cell = Cell::stochastic("stochastic_laplacian", e, 16, W_MLP, 4, s);
            m.push(if s == 16 && e == VmCol { cell.reduced() } else { cell });
        }
    }
    for e in [JetStd, JetCol, VmCol] {
        let cell = Cell::stochastic("stochastic_biharmonic", e, 8, W_BIH, 4, 16);
        m.push(if e == JetCol { cell.reduced() } else { cell });
    }
    // Depth scaling on the deep net.
    m.push(Cell::exact("laplacian", Nested, 16, W_DEEP, 8).heavy());
    m.push(Cell::exact("laplacian", JetCol, 16, W_DEEP, 8));
    m.push(Cell::exact("laplacian", VmCol, 16, W_DEEP, 8).reduced());
    // f32 execution: the collapsed VM program cast to single precision
    // (the Precision::F32 serving path), on the fig1 headliners.
    m.push(Cell::exact("laplacian", VmColF32, 16, W_MLP, 8).reduced());
    m.push(Cell::exact("laplacian", VmColF32, 16, W_MLP, 32));
    m.push(Cell::exact("helmholtz", VmColF32, 16, W_MLP, 8));
    // Training steps: reverse-over-collapsed-forward θ-gradients through
    // the cached forward+backward pair (docs/training.md) — the steady-
    // state cost of one `pinn_step`, compile excluded.
    m.push(Cell::exact("laplacian", Grad, 16, W_MLP, 8).reduced());
    m.push(Cell::exact("laplacian", GradF32, 16, W_MLP, 8));
    // Raw GEMM kernels: the 256³ headline and an MLP-layer-like shape.
    m.push(Cell::gemm(GemmRef, 256, 256, 256).heavy());
    m.push(Cell::gemm(Gemm, 256, 256, 256).heavy().reduced());
    m.push(Cell::gemm(GemmF32, 256, 256, 256).heavy().reduced());
    m.push(Cell::gemm(GemmRef, 4096, 32, 1));
    m.push(Cell::gemm(Gemm, 4096, 32, 1));
    m.push(Cell::gemm(GemmF32, 4096, 32, 1));
    m
}

/// The reduced matrix the CI barometer job runs: the `reduced`-flagged
/// subset of [`full_matrix`].
pub fn reduced_matrix() -> Vec<Cell> {
    full_matrix().into_iter().filter(|c| c.reduced).collect()
}

/// Look a cell up by its id (searching the full matrix).
pub fn find_cell(id: &str) -> Option<Cell> {
    full_matrix().into_iter().find(|c| c.id() == id)
}

/// Deterministic per-cell seed: FNV-1a over the id, masked to 31 bits so
/// the value survives the f64 round-trip of the JSON record exactly.
pub fn cell_seed(id: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in id.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h & 0x7fff_ffff
}

/// `GITHUB_SHA` in CI, else the working tree's short HEAD, else `unknown`.
pub fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// The `env` fingerprint recorded with every cell: enough to tell two
/// machines' snapshots apart, nothing personally identifying. `host`
/// comes from `CTAYLOR_BENCH_HOST` when set (CI sets it to the runner
/// label), `threads` honors `CTAYLOR_THREADS`.
pub fn env_fingerprint() -> Json {
    let threads = std::env::var("CTAYLOR_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    let host = std::env::var("CTAYLOR_BENCH_HOST").unwrap_or_else(|_| "unknown".into());
    Json::obj(vec![
        ("arch", Json::str(std::env::consts::ARCH)),
        ("host", Json::str(&host)),
        ("os", Json::str(std::env::consts::OS)),
        ("threads", Json::num(threads as f64)),
    ])
}

fn measure<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Vec<u64> {
    for _ in 0..warmup {
        f();
    }
    let iters = iters.max(1);
    let mut ns = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        ns.push(t0.elapsed().as_nanos() as u64);
    }
    ns
}

fn ns_stats(samples: &mut [u64]) -> (u64, u64, u64, usize) {
    samples.sort_unstable();
    let n = samples.len();
    (samples[n / 2], samples[0], samples[n - 1], n)
}

fn theta_len(dim: usize, widths: &[usize]) -> usize {
    let mut prev = dim;
    let mut total = 0;
    for &w in widths {
        total += prev * w + w;
        prev = w;
    }
    total
}

/// The analytic FLOP/memory proxies for a cell, from the paper's
/// propagated-vector cost model (`taylor::count`). Kernel cells use the
/// exact GEMM arithmetic instead.
pub fn cell_proxy(cell: &Cell) -> count::CostProxy {
    if cell.op == "gemm" {
        let (m, k, n) = (cell.widths[0], cell.widths[1], cell.widths[2]);
        let esz = if cell.engine == EngineKind::GemmF32 { 4 } else { 8 };
        return count::CostProxy {
            vectors: 0,
            flops: 2.0 * (m * k * n) as f64,
            mem_diff_bytes: ((m * k + k * n + m * n) * esz) as f64,
            mem_nondiff_bytes: ((m * k + k * n + m * n) * esz) as f64,
        };
    }
    let (op, mode) = match cell.op.strip_prefix("stochastic_") {
        Some(base) => (base, "stochastic"),
        None => (cell.op, "exact"),
    };
    count::route_proxy(
        op,
        cell.engine.method(),
        mode,
        cell.dim,
        cell.samples,
        count::NetShape {
            batch: cell.batch,
            widths: &cell.widths,
            theta_len: theta_len(cell.dim, &cell.widths),
        },
    )
}

fn spec_for(cell: &Cell, sto_dirs: Option<&Tensor>) -> Result<OperatorSpec> {
    Ok(match cell.op {
        "laplacian" => OperatorSpec::laplacian(cell.dim),
        "weighted_laplacian" => OperatorSpec::weighted_laplacian(&operators::basis(cell.dim)),
        "helmholtz" => OperatorSpec::helmholtz_preset(cell.dim),
        "biharmonic" => OperatorSpec::biharmonic(cell.dim),
        "stochastic_laplacian" => {
            OperatorSpec::stochastic_laplacian(sto_dirs.context("stochastic cell without dirs")?)
        }
        "stochastic_biharmonic" => {
            OperatorSpec::stochastic_biharmonic(sto_dirs.context("stochastic cell without dirs")?)
        }
        other => bail!("no operator spec for cell op {other:?}"),
    })
}

/// Graph/VM outputs must agree with the jet-engine oracle before anything
/// is timed: a fast wrong answer is not a benchmark.
fn check_against_oracle(cell: &Cell, mlp: &Mlp, x: &Tensor, oplan: &OperatorPlan, out: &[Tensor]) -> Result<()> {
    let (f0, op) = plan::apply(mlp, x, oplan, Collapse::Collapsed);
    let scale = op.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    ensure!(
        out[0].max_abs_diff(&f0) < 1e-8,
        "cell {}: f(x_0) deviates from the jet oracle",
        cell.id()
    );
    ensure!(
        out[1].max_abs_diff(&op) < 1e-8 * scale,
        "cell {}: operator output deviates from the jet oracle",
        cell.id()
    );
    Ok(())
}

/// f32 cells run against the same f64 jet oracle, at single-precision
/// tolerances (docs/METHODOLOGY.md, cross-precision comparison semantics).
fn check_f32_against_oracle(
    cell: &Cell,
    mlp: &Mlp,
    x: &Tensor,
    oplan: &OperatorPlan,
    out: &[Tensor<f32>],
) -> Result<()> {
    let (f0, op) = plan::apply(mlp, x, oplan, Collapse::Collapsed);
    let scale = op.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    let (f0_32, op_32): (Tensor, Tensor) = (out[0].cast(), out[1].cast());
    ensure!(
        f0_32.max_abs_diff(&f0) < 1e-4,
        "cell {}: f32 f(x_0) deviates from the jet oracle",
        cell.id()
    );
    ensure!(
        op_32.max_abs_diff(&op) < 1e-3 * scale,
        "cell {}: f32 operator output deviates from the jet oracle",
        cell.id()
    );
    Ok(())
}

fn run_gemm(cell: &Cell, seed: u64) -> Result<Vec<u64>> {
    let (m, k, n) = (cell.widths[0], cell.widths[1], cell.widths[2]);
    let mut rng = Rng::new(seed);
    let mut a = vec![0.0f64; m * k];
    let mut b = vec![0.0f64; k * n];
    for v in a.iter_mut() {
        *v = rng.normal();
    }
    for v in b.iter_mut() {
        *v = rng.normal();
    }
    if cell.engine == EngineKind::GemmF32 {
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let mut c32 = vec![0.0f32; m * n];
        return Ok(measure(
            || {
                kernels::gemm(m, k, n, &a32, &b32, &mut c32);
                std::hint::black_box(&c32);
            },
            cell.warmup,
            cell.iters,
        ));
    }
    let mut c = vec![0.0f64; m * n];
    let reference = cell.engine == EngineKind::GemmRef;
    ensure!(
        reference || cell.engine == EngineKind::Gemm,
        "cell {}: op gemm requires a kernel engine",
        cell.id()
    );
    Ok(measure(
        || {
            if reference {
                kernels::gemm_reference(m, k, n, &a, &b, &mut c);
            } else {
                kernels::gemm(m, k, n, &a, &b, &mut c);
            }
            std::hint::black_box(&c);
        },
        cell.warmup,
        cell.iters,
    ))
}

fn run_measured(cell: &Cell, seed: u64) -> Result<Vec<u64>> {
    use EngineKind::*;
    if cell.op == "gemm" {
        return run_gemm(cell, seed);
    }
    let mut rng = Rng::new(seed);
    let mlp = Mlp::init(&mut rng, cell.dim, &cell.widths, cell.batch);
    let x = mlp.random_input(&mut rng);
    let sto_dirs = (cell.samples > 0).then(|| {
        let mut d = vec![0.0f64; cell.samples * cell.dim];
        for v in d.iter_mut() {
            *v = rng.rademacher();
        }
        Tensor::new(vec![cell.samples, cell.dim], d)
    });
    let ns = match cell.engine {
        Nested => match cell.op {
            "laplacian" => measure(
                || {
                    std::hint::black_box(nested::laplacian(&mlp, &x, None, 1.0));
                },
                cell.warmup,
                cell.iters,
            ),
            "biharmonic" => measure(
                || {
                    std::hint::black_box(nested::biharmonic_tvp(&mlp, &x));
                },
                cell.warmup,
                cell.iters,
            ),
            other => bail!("the matrix has no nested-AD implementation for {other:?}"),
        },
        JetStd | JetCol => {
            let oplan = spec_for(cell, sto_dirs.as_ref())?.compile();
            let mode = if cell.engine == JetStd { Collapse::Standard } else { Collapse::Collapsed };
            measure(
                || {
                    std::hint::black_box(plan::apply(&mlp, &x, &oplan, mode));
                },
                cell.warmup,
                cell.iters,
            )
        }
        InterpCol => {
            let oplan = spec_for(cell, sto_dirs.as_ref())?.compile();
            let g = rewrite::collapse(
                &build_plan_jet_std(&mlp, &oplan, cell.batch),
                TAGGED_SLOTS,
                oplan.dirs.shape[0],
            );
            let inputs = [x.clone(), oplan.dirs.broadcast_rows(cell.batch)];
            check_against_oracle(cell, &mlp, &x, &oplan, &interp::eval(&g, &inputs)?)?;
            measure(
                || {
                    std::hint::black_box(interp::eval(&g, &inputs).unwrap());
                },
                cell.warmup,
                cell.iters,
            )
        }
        VmStd | VmCol | VmColF32 => {
            let oplan = spec_for(cell, sto_dirs.as_ref())?.compile();
            let g_std = build_plan_jet_std(&mlp, &oplan, cell.batch);
            let g = if cell.engine == VmStd {
                g_std
            } else {
                rewrite::collapse(&g_std, TAGGED_SLOTS, oplan.dirs.shape[0])
            };
            let num_dirs = oplan.dirs.shape[0];
            let shapes = vec![vec![cell.batch, cell.dim], vec![num_dirs, cell.batch, cell.dim]];
            let prog = program::compile(&g, &shapes)?;
            let inputs = [x.clone(), oplan.dirs.broadcast_rows(cell.batch)];
            if cell.engine == VmColF32 {
                let prog32: program::Program<f32> = prog.cast(false);
                let in32 = [inputs[0].cast::<f32>(), inputs[1].cast::<f32>()];
                check_f32_against_oracle(cell, &mlp, &x, &oplan, &prog32.execute(&in32)?)?;
                measure(
                    || {
                        std::hint::black_box(prog32.execute(&in32).unwrap());
                    },
                    cell.warmup,
                    cell.iters,
                )
            } else {
                check_against_oracle(cell, &mlp, &x, &oplan, &prog.execute(&inputs)?)?;
                measure(
                    || {
                        std::hint::black_box(prog.execute(&inputs).unwrap());
                    },
                    cell.warmup,
                    cell.iters,
                )
            }
        }
        Grad | GradF32 => {
            // One full training step through the typed API: the cached
            // forward+backward pair (`residual_grad`), θ a runtime input
            // so the steady state is pure VM execution — compile paid
            // once in warmup, cache hits thereafter.
            ensure!(cell.samples == 0, "cell {}: grad cells run the exact route", cell.id());
            let precision = if cell.engine == Grad {
                Precision::F64
            } else {
                Precision::F32 { accumulate_f64: false }
            };
            let engine = Engine::builder()
                .registry(Registry::builtin())
                .threads(1)
                .precision(precision)
                .build()
                .with_context(|| format!("cell {}: engine", cell.id()))?;
            let handle = engine
                .compile(spec_for(cell, None)?, Method::Collapsed, &cell.widths)
                .with_context(|| format!("cell {}: compile", cell.id()))?;
            let theta = handle.meta().glorot_theta(&mut rng);
            let mut xs = vec![0.0f32; cell.batch * cell.dim];
            rng.fill_normal_f32(&mut xs);
            let xh = HostTensor::new(vec![cell.batch, cell.dim], xs);
            let mut fs = vec![0.0f32; cell.batch];
            rng.fill_normal_f32(&mut fs);
            let forcing = HostTensor::new(vec![cell.batch, 1], fs);
            let grad_of = |t: &HostTensor| {
                handle
                    .residual_grad()
                    .theta(t)
                    .x(&xh)
                    .forcing(&forcing)
                    .run()
                    .with_context(|| format!("cell {}: residual_grad", cell.id()))
            };
            // The adjoint must agree with central finite differences at a
            // probe index before anything is timed: a fast wrong gradient
            // is not a benchmark.
            let out = grad_of(&theta)?;
            ensure!(out.loss.is_finite(), "cell {}: non-finite loss", cell.id());
            let k = theta.data.len() / 2;
            let eps = 1e-2f32;
            let mut plus = theta.clone();
            plus.data[k] += eps;
            let mut minus = theta.clone();
            minus.data[k] -= eps;
            let fd = (grad_of(&plus)?.loss - grad_of(&minus)?.loss)
                / f64::from(plus.data[k] - minus.data[k]);
            let got = f64::from(out.grad.data[k]);
            let scale = out.grad.data.iter().fold(1.0f64, |m, &g| m.max(f64::from(g).abs()));
            ensure!(
                (got - fd).abs() <= 2e-2 * (1.0 + scale),
                "cell {}: adjoint θ[{k}] = {got} deviates from central FD {fd}",
                cell.id()
            );
            measure(
                || {
                    std::hint::black_box(
                        handle
                            .residual_grad()
                            .theta(&theta)
                            .x(&xh)
                            .forcing(&forcing)
                            .run()
                            .unwrap(),
                    );
                },
                cell.warmup,
                cell.iters,
            )
        }
        GemmRef | Gemm | GemmF32 => {
            bail!("cell {}: kernel engines require the gemm op", cell.id())
        }
    };
    Ok(ns)
}

/// Run one cell in this process and return its record (one JSON object in
/// the `ctaylor-barometer/1` format documented at module level).
pub fn run_cell(cell: &Cell) -> Result<Json> {
    let id = cell.id();
    let seed = cell_seed(&id);
    let mut ns = run_measured(cell, seed)?;
    let proxy = cell_proxy(cell);
    let (median, min, max, n) = ns_stats(&mut ns);
    Ok(Json::obj(vec![
        ("format", Json::str(FORMAT)),
        ("id", Json::str(&id)),
        ("engine", Json::str(cell.engine.tag())),
        ("op", Json::str(cell.op)),
        ("dim", Json::num(cell.dim as f64)),
        ("widths", Json::arr(cell.widths.iter().map(|w| Json::num(*w as f64)))),
        ("batch", Json::num(cell.batch as f64)),
        ("samples", Json::num(cell.samples as f64)),
        ("seed", Json::num(seed as f64)),
        ("warmup", Json::num(cell.warmup as f64)),
        ("iters", Json::num(cell.iters as f64)),
        ("git_rev", Json::str(&git_rev())),
        (
            "wall_ns",
            Json::obj(vec![
                ("count", Json::num(n as f64)),
                ("max", Json::num(max as f64)),
                ("median", Json::num(median as f64)),
                ("min", Json::num(min as f64)),
            ]),
        ),
        (
            "proxies",
            Json::obj(vec![
                ("flops", Json::num(proxy.flops)),
                ("mem_diff_bytes", Json::num(proxy.mem_diff_bytes)),
                ("mem_nondiff_bytes", Json::num(proxy.mem_nondiff_bytes)),
                ("vectors", Json::num(proxy.vectors as f64)),
            ]),
        ),
        ("env", env_fingerprint()),
    ]))
}

/// One human-readable line for a record (the non-`--json` CLI output).
pub fn describe_record(record: &Json) -> String {
    let id = record.get_str("id").unwrap_or("?");
    let wall = record.get("wall_ns");
    let ms = |k: &str| wall.and_then(|w| w.get_f64(k)).unwrap_or(0.0) / 1e6;
    format!(
        "cell {id}: median {:.3}ms (min {:.3}ms, max {:.3}ms, {} iters)",
        ms("median"),
        ms("min"),
        ms("max"),
        wall.and_then(|w| w.get_usize("count")).unwrap_or(0),
    )
}

/// Spawn the release binary for one cell — process isolation per the
/// methodology — and parse the record off its last stdout line.
pub fn spawn_cell(bin: &Path, id: &str, warmup: Option<usize>, iters: Option<usize>) -> Result<Json> {
    let mut cmd = std::process::Command::new(bin);
    cmd.args(["bench", "run", "--cell", id, "--json"]);
    if let Some(w) = warmup {
        cmd.args(["--warmup", &w.to_string()]);
    }
    if let Some(i) = iters {
        cmd.args(["--iters", &i.to_string()]);
    }
    let out = cmd
        .output()
        .with_context(|| format!("spawning {} bench run --cell {id}", bin.display()))?;
    ensure!(
        out.status.success(),
        "cell {id} failed ({}): {}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .rev()
        .find(|l| !l.trim().is_empty())
        .with_context(|| format!("cell {id} printed no record"))?;
    json::parse(line).map_err(|e| anyhow!("cell {id}: unparseable record: {e}"))
}

/// Wrap per-cell records into a snapshot file body.
pub fn snapshot(records: Vec<Json>) -> Json {
    let created = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Json::obj(vec![
        ("format", Json::str(FORMAT)),
        ("git_rev", Json::str(&git_rev())),
        ("created_unix", Json::num(created as f64)),
        ("env", env_fingerprint()),
        ("cells", Json::Arr(records)),
    ])
}

/// Read a snapshot file and check its `format` tag.
pub fn load_snapshot<P: AsRef<Path>>(path: P) -> Result<Json> {
    let path = path.as_ref();
    let v = super::report::load_json(path)?;
    let fmt = v.get_str("format").unwrap_or("");
    ensure!(
        fmt == FORMAT,
        "{} has format {fmt:?}, expected {FORMAT:?}",
        path.display()
    );
    Ok(v)
}

/// Thresholds for [`cmp_records`].
#[derive(Debug, Clone, Copy)]
pub struct CmpConfig {
    /// Noise threshold in percent: |Δ| ≤ threshold classifies as unchanged.
    pub threshold_pct: f64,
    /// When set, the report fails if any regressed cell slowed by at
    /// least this percentage (use a value ≥ `threshold_pct`).
    pub fail_on_regress_pct: Option<f64>,
}

/// One joined cell: old/new median wall-ns and the percent change.
#[derive(Debug, Clone)]
pub struct CellDelta {
    pub id: String,
    pub old_ns: f64,
    pub new_ns: f64,
    /// `(new/old - 1) * 100`; positive = slower.
    pub pct: f64,
}

/// The result of diffing two snapshots.
#[derive(Debug, Clone)]
pub struct CmpReport {
    pub threshold_pct: f64,
    pub fail_pct: Option<f64>,
    /// Slower beyond the threshold, worst first.
    pub regressions: Vec<CellDelta>,
    /// Faster beyond the threshold, best first.
    pub improvements: Vec<CellDelta>,
    /// Within the noise threshold.
    pub unchanged: Vec<CellDelta>,
    /// Cell ids only in the new snapshot.
    pub added: Vec<String>,
    /// Cell ids only in the old snapshot.
    pub retired: Vec<String>,
    /// True iff `fail_pct` is set and some regression reaches it.
    pub failed: bool,
}

fn cells_by_id(snap: &Json) -> Result<BTreeMap<String, f64>> {
    let cells = snap
        .get("cells")
        .and_then(Json::as_arr)
        .context("snapshot has no `cells` array")?;
    let mut map = BTreeMap::new();
    for c in cells {
        let id = c.get_str("id").context("cell record without an `id`")?;
        let median = c
            .get("wall_ns")
            .and_then(|w| w.get_f64("median"))
            .with_context(|| format!("cell {id} has no wall_ns.median"))?;
        map.insert(id.to_string(), median);
    }
    Ok(map)
}

/// Join two snapshots by cell id on `wall_ns.median` and classify every
/// shared cell against the noise threshold. Ids on one side only are
/// reported (`added` / `retired`), never an error — the rule that lets
/// the matrix evolve without breaking old snapshots.
pub fn cmp_records(old: &Json, new: &Json, cfg: &CmpConfig) -> Result<CmpReport> {
    let old_cells = cells_by_id(old)?;
    let new_cells = cells_by_id(new)?;
    let mut regressions = Vec::new();
    let mut improvements = Vec::new();
    let mut unchanged = Vec::new();
    let mut retired = Vec::new();
    for (id, &old_ns) in &old_cells {
        let Some(&new_ns) = new_cells.get(id) else {
            retired.push(id.clone());
            continue;
        };
        let pct = if old_ns > 0.0 { (new_ns / old_ns - 1.0) * 100.0 } else { 0.0 };
        let d = CellDelta { id: id.clone(), old_ns, new_ns, pct };
        if pct > cfg.threshold_pct {
            regressions.push(d);
        } else if pct < -cfg.threshold_pct {
            improvements.push(d);
        } else {
            unchanged.push(d);
        }
    }
    let added: Vec<String> =
        new_cells.keys().filter(|k| !old_cells.contains_key(*k)).cloned().collect();
    regressions.sort_by(|a, b| b.pct.partial_cmp(&a.pct).unwrap());
    improvements.sort_by(|a, b| a.pct.partial_cmp(&b.pct).unwrap());
    let failed =
        cfg.fail_on_regress_pct.is_some_and(|p| regressions.iter().any(|d| d.pct >= p));
    Ok(CmpReport {
        threshold_pct: cfg.threshold_pct,
        fail_pct: cfg.fail_on_regress_pct,
        regressions,
        improvements,
        unchanged,
        added,
        retired,
        failed,
    })
}

impl CmpReport {
    /// The human-readable regression report.
    pub fn render_text(&self) -> String {
        let row = |d: &CellDelta, status: &str| {
            vec![
                d.id.clone(),
                format!("{:.3}", d.old_ns / 1e6),
                format!("{:.3}", d.new_ns / 1e6),
                format!("{:+.1}%", d.pct),
                status.to_string(),
            ]
        };
        let mut rows = Vec::new();
        for d in &self.regressions {
            rows.push(row(d, "REGRESSED"));
        }
        for d in &self.improvements {
            rows.push(row(d, "improved"));
        }
        for d in &self.unchanged {
            rows.push(row(d, "~"));
        }
        let mut out = String::from("# barometer cmp — median wall-clock per cell\n\n");
        out.push_str(&table(&["cell", "old [ms]", "new [ms]", "delta", "status"], &rows));
        for id in &self.added {
            out.push_str(&format!("added:   {id}\n"));
        }
        for id in &self.retired {
            out.push_str(&format!("retired: {id}\n"));
        }
        out.push_str(&format!(
            "\n{} regressed, {} improved, {} unchanged within the ±{}% noise threshold\n",
            self.regressions.len(),
            self.improvements.len(),
            self.unchanged.len(),
            self.threshold_pct,
        ));
        if let Some(p) = self.fail_pct {
            out.push_str(&format!(
                "fail-on-regress at +{p}%: {}\n",
                if self.failed { "FAIL" } else { "ok" }
            ));
        }
        out
    }

    /// The single-line machine summary (`ctaylor-barometer-cmp/1`): the
    /// last line `ctaylor bench cmp` prints, naming every regressed and
    /// improved cell with old/new medians and the percent change.
    pub fn summary_json(&self) -> Json {
        let deltas = |v: &[CellDelta]| {
            Json::arr(v.iter().map(|d| {
                Json::obj(vec![
                    ("id", Json::str(&d.id)),
                    ("new_ns", Json::num(d.new_ns)),
                    ("old_ns", Json::num(d.old_ns)),
                    ("pct", Json::num((d.pct * 100.0).round() / 100.0)),
                ])
            }))
        };
        Json::obj(vec![
            ("format", Json::str(CMP_FORMAT)),
            ("threshold_pct", Json::num(self.threshold_pct)),
            ("fail", Json::Bool(self.failed)),
            ("regressions", deltas(&self.regressions)),
            ("improvements", deltas(&self.improvements)),
            ("unchanged", Json::num(self.unchanged.len() as f64)),
            ("added", Json::arr(self.added.iter().map(|s| Json::str(s)))),
            ("retired", Json::arr(self.retired.iter().map(|s| Json::str(s)))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_ids_are_stable() {
        // Record-format stability: these exact strings are join keys in
        // committed snapshots; changing them breaks the trajectory.
        let c = Cell::exact("laplacian", EngineKind::VmCol, 16, W_MLP, 8);
        assert_eq!(c.id(), "laplacian-d16-w32x32x1-b8-vm-col");
        let c32 = Cell::exact("laplacian", EngineKind::VmColF32, 16, W_MLP, 8);
        assert_eq!(c32.id(), "laplacian-d16-w32x32x1-b8-vm-col-f32");
        let gr = Cell::exact("laplacian", EngineKind::Grad, 16, W_MLP, 8);
        assert_eq!(gr.id(), "laplacian-d16-w32x32x1-b8-grad");
        let gr32 = Cell::exact("laplacian", EngineKind::GradF32, 16, W_MLP, 8);
        assert_eq!(gr32.id(), "laplacian-d16-w32x32x1-b8-grad-f32");
        let s = Cell::stochastic("stochastic_laplacian", EngineKind::JetCol, 16, W_MLP, 4, 16);
        assert_eq!(s.id(), "stochastic_laplacian-d16-w32x32x1-b4-s16-jet-col");
        let g = Cell::gemm(EngineKind::Gemm, 256, 256, 256);
        assert_eq!(g.id(), "gemm-256x256x256-tiled");
        let g32 = Cell::gemm(EngineKind::GemmF32, 256, 256, 256);
        assert_eq!(g32.id(), "gemm-256x256x256-tiled-f32");
    }

    #[test]
    fn matrix_ids_are_unique_and_findable() {
        let m = full_matrix();
        let ids: std::collections::BTreeSet<String> = m.iter().map(Cell::id).collect();
        assert_eq!(ids.len(), m.len(), "duplicate cell ids in the matrix");
        for id in &ids {
            assert_eq!(find_cell(id).map(|c| c.id()).as_deref(), Some(id.as_str()));
        }
    }

    #[test]
    fn reduced_matrix_is_a_subset() {
        let full: std::collections::BTreeSet<String> = full_matrix().iter().map(Cell::id).collect();
        let reduced = reduced_matrix();
        assert!(reduced.len() >= 8, "reduced matrix too small: {}", reduced.len());
        assert!(reduced.len() < full.len());
        for c in &reduced {
            assert!(full.contains(&c.id()));
        }
    }

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let a = cell_seed("laplacian-d16-w32x32x1-b8-vm-col");
        assert_eq!(a, cell_seed("laplacian-d16-w32x32x1-b8-vm-col"));
        assert_ne!(a, cell_seed("laplacian-d16-w32x32x1-b8-jet-col"));
        assert!(a <= 0x7fff_ffff);
    }

    #[test]
    fn proxies_follow_the_count_model() {
        let std_cell = Cell::exact("laplacian", EngineKind::VmStd, 16, W_MLP, 8);
        let col_cell = Cell::exact("laplacian", EngineKind::VmCol, 16, W_MLP, 8);
        let p_std = cell_proxy(&std_cell);
        let p_col = cell_proxy(&col_cell);
        assert_eq!(p_std.vectors, count::laplacian_standard(16));
        assert_eq!(p_col.vectors, count::laplacian_collapsed(16));
        assert!(p_col.flops < p_std.flops);
        let g = cell_proxy(&Cell::gemm(EngineKind::Gemm, 4, 5, 6));
        assert_eq!(g.flops, 240.0);
        assert_eq!(g.vectors, 0);
    }

    fn tiny(op: &'static str, engine: EngineKind, dim: usize) -> Cell {
        Cell {
            warmup: 0,
            iters: 2,
            ..Cell::exact(op, engine, dim, &[8, 1], 2)
        }
    }

    #[test]
    fn run_cell_produces_a_complete_record() {
        let record = run_cell(&tiny("laplacian", EngineKind::JetCol, 4)).unwrap();
        assert_eq!(record.get_str("format"), Some(FORMAT));
        assert_eq!(record.get_str("id"), Some("laplacian-d4-w8x1-b2-jet-col"));
        assert_eq!(record.get_usize("samples"), Some(0));
        let wall = record.get("wall_ns").unwrap();
        assert_eq!(wall.get_usize("count"), Some(2));
        assert!(wall.get_f64("median").unwrap() > 0.0);
        assert!(wall.get_f64("min").unwrap() <= wall.get_f64("max").unwrap());
        assert!(record.get("proxies").unwrap().get_f64("flops").unwrap() > 0.0);
        assert!(record.get("env").unwrap().get_str("os").is_some());
        // The record is the single-line wire format: it must round-trip.
        let line = json::to_string(&record);
        assert!(!line.contains('\n'));
        assert_eq!(json::parse(&line).unwrap(), record);
    }

    #[test]
    fn run_cell_covers_every_engine_family() {
        // One tiny cell per engine family keeps the full dispatch tested
        // without a release-build benchmark run.
        let engines = [
            EngineKind::Nested,
            EngineKind::VmStd,
            EngineKind::VmCol,
            EngineKind::VmColF32,
            EngineKind::InterpCol,
            EngineKind::Grad,
            EngineKind::GradF32,
        ];
        for engine in engines {
            let r = run_cell(&tiny("laplacian", engine, 4)).unwrap();
            assert!(r.get("wall_ns").unwrap().get_f64("median").unwrap() > 0.0, "{engine:?}");
        }
        for engine in [EngineKind::Gemm, EngineKind::GemmF32] {
            let mut g = Cell::gemm(engine, 8, 8, 8);
            g.warmup = 0;
            g.iters = 2;
            assert!(run_cell(&g).is_ok(), "{engine:?}");
        }
        let sto = Cell {
            warmup: 0,
            iters: 2,
            ..Cell::stochastic("stochastic_laplacian", EngineKind::VmCol, 4, &[8, 1], 2, 4)
        };
        assert!(run_cell(&sto).is_ok());
    }

    fn snap(cells: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("format", Json::str(FORMAT)),
            ("git_rev", Json::str("test")),
            ("created_unix", Json::num(0.0)),
            ("env", env_fingerprint()),
            (
                "cells",
                Json::arr(cells.iter().map(|(id, ns)| {
                    Json::obj(vec![
                        ("id", Json::str(id)),
                        ("wall_ns", Json::obj(vec![("median", Json::num(*ns))])),
                    ])
                })),
            ),
        ])
    }

    #[test]
    fn cmp_classifies_against_the_threshold() {
        let old = snap(&[("a", 1000.0), ("b", 1000.0), ("c", 1000.0), ("gone", 5.0)]);
        let new = snap(&[("a", 1500.0), ("b", 600.0), ("c", 1030.0), ("fresh", 7.0)]);
        let cfg = CmpConfig { threshold_pct: 5.0, fail_on_regress_pct: None };
        let rep = cmp_records(&old, &new, &cfg).unwrap();
        assert_eq!(rep.regressions.len(), 1);
        assert_eq!(rep.regressions[0].id, "a");
        assert!((rep.regressions[0].pct - 50.0).abs() < 1e-9);
        assert_eq!(rep.improvements.len(), 1);
        assert_eq!(rep.improvements[0].id, "b");
        assert_eq!(rep.unchanged.len(), 1);
        assert_eq!(rep.added, vec!["fresh".to_string()]);
        assert_eq!(rep.retired, vec!["gone".to_string()]);
        assert!(!rep.failed);
    }

    #[test]
    fn fail_on_regress_trips_at_its_own_threshold() {
        let old = snap(&[("a", 1000.0), ("b", 1000.0)]);
        let new = snap(&[("a", 1080.0), ("b", 1000.0)]);
        let lenient =
            cmp_records(&old, &new, &CmpConfig { threshold_pct: 5.0, fail_on_regress_pct: Some(10.0) })
                .unwrap();
        assert_eq!(lenient.regressions.len(), 1);
        assert!(!lenient.failed, "8% regression must not trip a 10% gate");
        let strict =
            cmp_records(&old, &new, &CmpConfig { threshold_pct: 5.0, fail_on_regress_pct: Some(8.0) })
                .unwrap();
        assert!(strict.failed);
    }

    #[test]
    fn summary_json_names_the_regressed_cells_on_one_line() {
        let old = snap(&[("slow-cell", 1000.0)]);
        let new = snap(&[("slow-cell", 2000.0)]);
        let rep = cmp_records(
            &old,
            &new,
            &CmpConfig { threshold_pct: 5.0, fail_on_regress_pct: Some(50.0) },
        )
        .unwrap();
        let line = json::to_string(&rep.summary_json());
        assert!(!line.contains('\n'));
        let parsed = json::parse(&line).unwrap();
        assert_eq!(parsed.get_str("format"), Some(CMP_FORMAT));
        assert_eq!(parsed.get("fail"), Some(&Json::Bool(true)));
        let regs = parsed.get("regressions").unwrap().as_arr().unwrap();
        assert_eq!(regs[0].get_str("id"), Some("slow-cell"));
        assert!((regs[0].get_f64("pct").unwrap() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn cmp_rejects_a_malformed_snapshot() {
        let bad = Json::obj(vec![("format", Json::str(FORMAT))]);
        let good = snap(&[("a", 1.0)]);
        let cfg = CmpConfig { threshold_pct: 5.0, fail_on_regress_pct: None };
        assert!(cmp_records(&bad, &good, &cfg).is_err());
    }

    #[test]
    fn render_text_reports_every_bucket() {
        let old = snap(&[("a", 1000.0), ("b", 1000.0), ("gone", 5.0)]);
        let new = snap(&[("a", 2000.0), ("b", 1000.0), ("fresh", 7.0)]);
        let rep = cmp_records(
            &old,
            &new,
            &CmpConfig { threshold_pct: 5.0, fail_on_regress_pct: Some(10.0) },
        )
        .unwrap();
        let text = rep.render_text();
        assert!(text.contains("REGRESSED"));
        assert!(text.contains("added:   fresh"));
        assert!(text.contains("retired: gone"));
        assert!(text.contains("FAIL"));
    }
}
