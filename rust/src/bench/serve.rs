//! `bench serve`: the serving-tier scenario suite.
//!
//! Each scenario drives a real [`Service`] under a characteristic load
//! shape — steady closed-loop, route fan-out, single-route fan-in, a
//! shard-scaling A/B, and open-loop Poisson chaos with overload — and
//! emits one versioned single-line JSON summary ([`FORMAT`]) with
//! client-observed latency quantiles, throughput, shed count, padding
//! ratio and oracle verdicts.  Replies are spot-checked (every reply, in
//! chaos) against a direct [`Engine`] evaluation of the same points under
//! the service's deterministic model ([`model_theta`] / [`model_sigma`]),
//! so a scenario that "passes" proved correctness, not just liveness.
//! The `--scenario all` driver spawns the release binary once per
//! scenario (process isolation, same discipline as the barometer).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::barometer::{env_fingerprint, git_rev};
use crate::api::Engine;
use crate::coordinator::server::{Client, ClientConfig, Server, ServerConfig, ServerError};
use crate::coordinator::{
    model_sigma, model_theta, FaultPlan, Metrics, RouteKey, Router, Service, ServiceConfig,
    SubmitError,
};
use crate::runtime::{HostTensor, Registry};
use crate::util::json::{self, Json};
use crate::util::prng::Rng;

/// Version tag on every summary line; bump on any schema change.
pub const FORMAT: &str = "ctaylor-serve/1";

/// The scenario suite, in the order the `all` driver runs it.
pub const SCENARIOS: [&str; 6] = ["baseline", "fanout", "fanin", "scale", "chaos", "faults"];

/// One-line human description of a scenario.
pub fn describe(name: &str) -> &'static str {
    match name {
        "baseline" => "4 closed-loop clients on one exact route, mixed request sizes",
        "fanout" => "8 closed-loop clients round-robining every route in the manifest",
        "fanin" => "8 closed-loop clients converging on one route with tiny requests",
        "scale" => "same multi-route load on 1 shard then N shards; reports the speedup",
        "chaos" => "open-loop Poisson arrivals, random deadlines, small queues, overload",
        "faults" => "TCP clients under injected shard panics/stalls/drops; bitwise recovery",
        _ => "unknown scenario",
    }
}

/// Knobs shared by every scenario.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Load-generation window per scenario (drain excluded).
    pub duration: Duration,
    /// Shard workers; 0 = available parallelism.
    pub shards: usize,
    /// Service seed: fixes θ/σ so the oracle can recompute them.
    pub seed: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { duration: Duration::from_millis(2000), shards: 0, seed: 0xC0FFEE }
    }
}

struct Route {
    key: RouteKey,
    dim: usize,
}

fn route_table(registry: &Registry) -> Vec<Route> {
    let router = Router::from_registry(registry);
    router
        .routes()
        .map(|key| {
            let dim = registry
                .artifacts
                .iter()
                .find(|a| a.op == key.op && a.method == key.method && a.mode == key.mode)
                .map(|a| a.dim)
                .unwrap_or(16);
            Route { key: key.clone(), dim }
        })
        .collect()
}

fn route_one(registry: &Registry, op: &str, method: &str, mode: &str) -> Result<Vec<Route>> {
    let key = RouteKey::new(op, method, mode);
    let route = route_table(registry)
        .into_iter()
        .find(|r| r.key == key)
        .with_context(|| format!("route {key} not in the manifest"))?;
    Ok(vec![route])
}

// ---------------------------------------------------------------------------
// Oracle: recompute served replies directly through the engine
// ---------------------------------------------------------------------------

/// Re-evaluates served points directly against an [`Engine`] under the
/// service's deterministic model.  Exact routes must match f0 *and* the
/// operator value; stochastic routes must match f0 (direction-independent)
/// and return finite estimates.
struct Oracle {
    engine: Engine,
    router: Router,
    seed: u64,
    models: BTreeMap<String, (HostTensor, Option<HostTensor>)>,
    dir_rng: Rng,
}

fn close(got: f32, want: f32) -> bool {
    let (g, w) = (f64::from(got), f64::from(want));
    (g - w).abs() <= 1e-4 * (1.0 + w.abs())
}

impl Oracle {
    fn new(registry: &Registry, seed: u64) -> Result<Oracle> {
        let router = Router::from_registry(registry);
        let engine = Engine::builder().registry(registry.clone()).threads(1).build()?;
        Ok(Oracle {
            engine,
            router,
            seed,
            models: BTreeMap::new(),
            dir_rng: Rng::new(seed ^ 0xD15),
        })
    }

    /// Direct-engine evaluation of `points` under the service's model at
    /// the route's largest ladder size: `(f0, op, stochastic)`.
    fn expected(
        &mut self,
        route: &RouteKey,
        dim: usize,
        points: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, bool)> {
        let sizes = self.router.batch_sizes(route)?;
        let b = *sizes.last().unwrap();
        let name = self.router.artifact(route, b)?.to_string();
        let handle = self.engine.operator(&name)?;
        let meta = handle.meta();
        let stochastic = meta.mode == "stochastic";
        let (samples, gaussian) = (meta.samples, meta.op == "biharmonic");
        if !self.models.contains_key(&name) {
            let theta = model_theta(self.seed, meta);
            let sigma =
                (meta.op == "weighted_laplacian").then(|| model_sigma(self.seed, meta));
            self.models.insert(name.clone(), (theta, sigma));
        }
        let (theta, sigma) = self.models.get(&name).unwrap();

        let n = points.len() / dim;
        let mut exp_f0 = Vec::with_capacity(n);
        let mut exp_op = Vec::with_capacity(n);
        for start in (0..n).step_by(b) {
            let take = (n - start).min(b);
            let mut x = vec![0.0f32; b * dim];
            x[..take * dim].copy_from_slice(&points[start * dim..(start + take) * dim]);
            let xt = HostTensor::new(vec![b, dim], x);
            let dirs = stochastic.then(|| {
                let mut d = vec![0.0f32; samples * dim];
                if gaussian {
                    self.dir_rng.fill_normal_f32(&mut d);
                } else {
                    self.dir_rng.fill_rademacher_f32(&mut d);
                }
                HostTensor::new(vec![samples, dim], d)
            });
            let mut req = handle.eval().theta(theta).x(&xt);
            if let Some(d) = &dirs {
                req = req.directions(d);
            } else if let Some(s) = sigma {
                req = req.sigma(s);
            }
            let out = req.run()?;
            exp_f0.extend_from_slice(&out.f0.data[..take]);
            exp_op.extend_from_slice(&out.op.data[..take]);
        }
        Ok((exp_f0, exp_op, stochastic))
    }

    /// Number of served values that disagree with a direct evaluation.
    fn check(
        &mut self,
        route: &RouteKey,
        dim: usize,
        points: &[f32],
        f0: &[f32],
        op: &[f32],
    ) -> Result<u64> {
        let n = points.len() / dim;
        ensure!(f0.len() == n && op.len() == n, "reply length mismatch: {n} points");
        let (exp_f0, exp_op, stochastic) = self.expected(route, dim, points)?;
        let mut bad = 0u64;
        for i in 0..n {
            if !close(f0[i], exp_f0[i]) {
                bad += 1;
            }
            if stochastic {
                if !op[i].is_finite() {
                    bad += 1;
                }
            } else if !close(op[i], exp_op[i]) {
                bad += 1;
            }
        }
        Ok(bad)
    }

    /// Bit-for-bit comparison for exact routes whose requests were sized
    /// to the ladder's largest block: service and oracle then execute
    /// identical blocks (the GEMM takes batch-size-dependent code paths,
    /// so bitwise equality only holds at equal block shapes), and every
    /// value must match to the bit.  Used by the faults scenario to
    /// prove a restarted shard is *identical*, not merely close.
    fn check_bitwise(
        &mut self,
        route: &RouteKey,
        dim: usize,
        points: &[f32],
        f0: &[f32],
        op: &[f32],
    ) -> Result<u64> {
        let n = points.len() / dim;
        ensure!(f0.len() == n && op.len() == n, "reply length mismatch: {n} points");
        let (exp_f0, exp_op, stochastic) = self.expected(route, dim, points)?;
        ensure!(!stochastic, "bitwise oracle only covers exact routes ({route})");
        let mut bad = 0u64;
        for i in 0..n {
            if f0[i].to_bits() != exp_f0[i].to_bits() {
                bad += 1;
            }
            if op[i].to_bits() != exp_op[i].to_bits() {
                bad += 1;
            }
        }
        Ok(bad)
    }
}

// ---------------------------------------------------------------------------
// Load generation
// ---------------------------------------------------------------------------

/// A reply retained for oracle checking.
struct Sample {
    route: usize,
    points: Vec<f32>,
    f0: Vec<f32>,
    op: Vec<f32>,
}

#[derive(Default)]
struct ClientOut {
    latencies_ms: Vec<f64>,
    requests: u64,
    points: u64,
    shed: u64,
    errors: u64,
    samples: Vec<Sample>,
}

/// Closed-loop clients: each thread submits, blocks on the reply, and
/// immediately submits again — the steady-state pattern of a VMC or PINN
/// training loop.  Every `sample_every`-th reply is kept for the oracle.
fn closed_loop(
    svc: &Service,
    routes: &[Route],
    clients: usize,
    max_points: usize,
    duration: Duration,
    seed: u64,
    sample_every: usize,
) -> Vec<ClientOut> {
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let mut rng = Rng::new(seed ^ 0x5bd1_e995u64.wrapping_mul(c as u64 + 1));
                    let mut out = ClientOut::default();
                    let end = Instant::now() + duration;
                    let mut sent = c; // offset so clients interleave routes
                    while Instant::now() < end {
                        let ri = sent % routes.len();
                        let route = &routes[ri];
                        sent += 1;
                        let n = 1 + rng.below(max_points);
                        let mut pts = vec![0.0f32; n * route.dim];
                        rng.fill_normal_f32(&mut pts);
                        let keep = sent % sample_every == 0;
                        let saved = if keep { pts.clone() } else { Vec::new() };
                        let t0 = Instant::now();
                        match svc.eval_blocking(route.key.clone(), pts, route.dim) {
                            Ok(resp) => {
                                out.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                                out.requests += 1;
                                out.points += n as u64;
                                if keep {
                                    out.samples.push(Sample {
                                        route: ri,
                                        points: saved,
                                        f0: resp.f0,
                                        op: resp.op,
                                    });
                                }
                            }
                            Err(e) => match e.downcast_ref::<SubmitError>() {
                                Some(SubmitError::Overloaded { .. }) => out.shed += 1,
                                _ => out.errors += 1,
                            },
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[derive(Default)]
struct Agg {
    latencies_ms: Vec<f64>,
    requests: u64,
    points: u64,
    shed: u64,
    errors: u64,
    oracle_checked: u64,
    oracle_failures: u64,
}

fn aggregate(outs: Vec<ClientOut>, routes: &[Route], oracle: &mut Oracle) -> Result<Agg> {
    let mut agg = Agg::default();
    for mut o in outs {
        agg.latencies_ms.append(&mut o.latencies_ms);
        agg.requests += o.requests;
        agg.points += o.points;
        agg.shed += o.shed;
        agg.errors += o.errors;
        for s in o.samples {
            let r = &routes[s.route];
            agg.oracle_checked += 1;
            if oracle.check(&r.key, r.dim, &s.points, &s.f0, &s.op)? > 0 {
                agg.oracle_failures += 1;
            }
        }
    }
    agg.latencies_ms.sort_by(f64::total_cmp);
    Ok(agg)
}

/// Quantile over a pre-sorted sample (nearest-rank).
fn pct(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// Server-side gauges captured before the service shuts down.
struct ServerSide {
    queue_p99_ms: f64,
    exec_p99_ms: f64,
    padding_ratio: f64,
}

fn server_side(m: &Metrics) -> ServerSide {
    ServerSide {
        queue_p99_ms: m.queue_wait.quantile_s(0.99) * 1e3,
        exec_p99_ms: m.execute.quantile_s(0.99) * 1e3,
        padding_ratio: m.padding_ratio(),
    }
}

fn summary(
    scenario: &str,
    shards: usize,
    wall_s: f64,
    agg: &Agg,
    server: &ServerSide,
    extra: Vec<(&str, Json)>,
) -> Json {
    // "ok" is a correctness verdict: oracle agreement and no rejections
    // other than typed overload shedding.  Throughput is informational.
    let ok = agg.oracle_failures == 0 && agg.errors == 0;
    let l = &agg.latencies_ms;
    let mut fields = vec![
        ("format", Json::str(FORMAT)),
        ("scenario", Json::str(scenario)),
        ("shards", Json::num(shards as f64)),
        ("duration_s", Json::num(wall_s)),
        ("requests", Json::num(agg.requests as f64)),
        ("points", Json::num(agg.points as f64)),
        ("shed", Json::num(agg.shed as f64)),
        ("errors", Json::num(agg.errors as f64)),
        ("p50_ms", Json::num(pct(l, 0.50))),
        ("p99_ms", Json::num(pct(l, 0.99))),
        ("p999_ms", Json::num(pct(l, 0.999))),
        ("queue_p99_ms", Json::num(server.queue_p99_ms)),
        ("exec_p99_ms", Json::num(server.exec_p99_ms)),
        ("throughput_pts_s", Json::num(agg.points as f64 / wall_s.max(1e-9))),
        ("padding_ratio", Json::num(server.padding_ratio)),
        ("oracle_checked", Json::num(agg.oracle_checked as f64)),
        ("oracle_failures", Json::num(agg.oracle_failures as f64)),
        ("ok", Json::Bool(ok)),
        ("git_rev", Json::str(&git_rev())),
        ("env", env_fingerprint()),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// One request per route covering every ladder size, so all compiles
/// leave the timed region (the same discipline as the coordinator bench).
fn warmup(svc: &Service, routes: &[Route]) -> Result<()> {
    for r in routes {
        let n: usize = svc.router().batch_sizes(&r.key)?.iter().sum();
        svc.eval_blocking(r.key.clone(), vec![0.1f32; n * r.dim], r.dim)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------------

/// Run one scenario in-process and return its summary JSON.
pub fn run_scenario(name: &str, registry: &Registry, opts: &ServeOpts) -> Result<Json> {
    match name {
        "baseline" => {
            let routes = route_one(registry, "laplacian", "collapsed", "exact")?;
            run_closed(registry, opts, "baseline", routes, 4, 16, 4)
        }
        "fanout" => run_closed(registry, opts, "fanout", route_table(registry), 8, 16, 8),
        "fanin" => {
            let routes = route_one(registry, "laplacian", "collapsed", "exact")?;
            run_closed(registry, opts, "fanin", routes, 8, 4, 8)
        }
        "scale" => scale(registry, opts),
        "chaos" => chaos(registry, opts),
        "faults" => faults(registry, opts),
        other => bail!("unknown scenario {other:?} ({})", SCENARIOS.join(" | ")),
    }
}

fn run_closed(
    registry: &Registry,
    opts: &ServeOpts,
    scenario: &str,
    routes: Vec<Route>,
    clients: usize,
    max_points: usize,
    sample_every: usize,
) -> Result<Json> {
    let cfg = ServiceConfig { shards: opts.shards, seed: opts.seed, ..ServiceConfig::default() };
    let svc = Service::start(registry.clone(), cfg)?;
    let shards = svc.shards();
    warmup(&svc, &routes)?;
    let t0 = Instant::now();
    let outs =
        closed_loop(&svc, &routes, clients, max_points, opts.duration, opts.seed, sample_every);
    let wall = t0.elapsed().as_secs_f64();
    let server = server_side(svc.metrics());
    let mut oracle = Oracle::new(registry, opts.seed)?;
    let agg = aggregate(outs, &routes, &mut oracle)?;
    svc.shutdown();
    Ok(summary(scenario, shards, wall, &agg, &server, Vec::new()))
}

/// The same multi-route closed-loop load on 1 shard, then on N shards
/// (one executor thread per shard in both phases, so the comparison
/// isolates shard parallelism from engine-internal batch sharding).
fn scale(registry: &Registry, opts: &ServeOpts) -> Result<Json> {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let multi = if opts.shards > 0 { opts.shards } else { avail.clamp(2, 4) };
    let mut phases = Vec::new();
    for shards in [1usize, multi] {
        let routes = route_table(registry);
        let cfg = ServiceConfig {
            shards,
            threads_per_shard: 1,
            seed: opts.seed,
            ..ServiceConfig::default()
        };
        let svc = Service::start(registry.clone(), cfg)?;
        warmup(&svc, &routes)?;
        let t0 = Instant::now();
        let outs = closed_loop(&svc, &routes, 8, 16, opts.duration, opts.seed, 8);
        let wall = t0.elapsed().as_secs_f64();
        let server = server_side(svc.metrics());
        let mut oracle = Oracle::new(registry, opts.seed)?;
        let agg = aggregate(outs, &routes, &mut oracle)?;
        svc.shutdown();
        phases.push((wall, agg, server));
    }
    let (wall_1, agg_1, _) = &phases[0];
    let (wall_m, agg_m, server_m) = &phases[1];
    let t1 = agg_1.points as f64 / wall_1.max(1e-9);
    let tm = agg_m.points as f64 / wall_m.max(1e-9);
    // Merge correctness across both phases; report load numbers from the
    // multi-shard phase, with the single-shard throughput as an extra.
    let agg = Agg {
        latencies_ms: agg_m.latencies_ms.clone(),
        requests: agg_m.requests,
        points: agg_m.points,
        shed: agg_1.shed + agg_m.shed,
        errors: agg_1.errors + agg_m.errors,
        oracle_checked: agg_1.oracle_checked + agg_m.oracle_checked,
        oracle_failures: agg_1.oracle_failures + agg_m.oracle_failures,
    };
    let extra = vec![
        ("throughput_1shard_pts_s", Json::num(t1)),
        ("speedup", Json::num(if t1 > 0.0 { tm / t1 } else { 0.0 })),
    ];
    Ok(summary("scale", multi, *wall_m, &agg, server_m, extra))
}

/// A reply still in flight during the chaos drain.
struct InFlight {
    route: usize,
    points: Vec<f32>,
    rx: std::sync::mpsc::Receiver<crate::coordinator::EvalReply>,
}

/// Open-loop Poisson arrivals with per-request random deadlines against
/// deliberately small shard queues: the service must shed with typed
/// overload errors only, and every admitted reply must pass the oracle.
fn chaos(registry: &Registry, opts: &ServeOpts) -> Result<Json> {
    const SUBMITTERS: usize = 2;
    /// Mean inter-arrival gap per submitter (exponential).
    const MEAN_GAP_S: f64 = 400e-6;
    let routes = route_table(registry);
    let cfg = ServiceConfig {
        shards: opts.shards,
        seed: opts.seed,
        queue_capacity: 48,
        ..ServiceConfig::default()
    };
    let svc = Service::start(registry.clone(), cfg)?;
    let shards = svc.shards();
    warmup(&svc, &routes)?;
    let t0 = Instant::now();
    let per_thread: Vec<(Vec<InFlight>, u64, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..SUBMITTERS)
            .map(|c| {
                let routes = &routes;
                let svc = &svc;
                s.spawn(move || {
                    let mut rng = Rng::new(opts.seed ^ 0xA5A5u64.wrapping_mul(c as u64 + 1));
                    let mut inflight = Vec::new();
                    let (mut shed, mut errors) = (0u64, 0u64);
                    let end = Instant::now() + opts.duration;
                    while Instant::now() < end {
                        let gap = -rng.uniform_in(1e-12, 1.0).ln() * MEAN_GAP_S;
                        std::thread::sleep(Duration::from_secs_f64(gap));
                        let ri = rng.below(routes.len());
                        let route = &routes[ri];
                        let n = 1 + rng.below(64);
                        let mut pts = vec![0.0f32; n * route.dim];
                        rng.fill_normal_f32(&mut pts);
                        let deadline = Duration::from_secs_f64(rng.uniform_in(2e-3, 10e-3));
                        let submitted = svc.submit_with_deadline(
                            route.key.clone(),
                            pts.clone(),
                            route.dim,
                            deadline,
                        );
                        match submitted {
                            Ok(rx) => inflight.push(InFlight { route: ri, points: pts, rx }),
                            Err(SubmitError::Overloaded { .. }) => shed += 1,
                            Err(_) => errors += 1,
                        }
                    }
                    (inflight, shed, errors)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Drain every in-flight reply and oracle-check ALL of them: chaos
    // passing means zero incorrect replies under overload, not "it
    // survived".
    let mut oracle = Oracle::new(registry, opts.seed)?;
    let mut agg = Agg::default();
    for (inflight, shed, errors) in per_thread {
        agg.shed += shed;
        agg.errors += errors;
        for f in inflight {
            match f.rx.recv() {
                Ok(Ok(resp)) => {
                    let r = &routes[f.route];
                    agg.requests += 1;
                    agg.points += (f.points.len() / r.dim) as u64;
                    agg.latencies_ms.push(resp.latency_s * 1e3);
                    agg.oracle_checked += 1;
                    if oracle.check(&r.key, r.dim, &f.points, &resp.f0, &resp.op)? > 0 {
                        agg.oracle_failures += 1;
                    }
                }
                Ok(Err(_)) | Err(_) => agg.errors += 1,
            }
        }
    }
    agg.latencies_ms.sort_by(f64::total_cmp);
    let wall = t0.elapsed().as_secs_f64();
    let server = server_side(svc.metrics());
    svc.shutdown();
    Ok(summary("chaos", shards, wall, &agg, &server, Vec::new()))
}

/// Per-client tallies for the faults scenario: every request must end in
/// exactly one of `samples`-worth of successes, a typed error, an
/// untyped error or a hang.
#[derive(Default)]
struct FaultClientOut {
    sent: u64,
    points: u64,
    latencies_ms: Vec<f64>,
    samples: Vec<Sample>,
    typed_errors: u64,
    error_kinds: BTreeMap<String, u64>,
    untyped_errors: u64,
    hangs: u64,
}

/// TCP clients driving exact routes over the real socket while a
/// deterministic [`FaultPlan`] panics, stalls and drops inside the shard
/// workers.  The verdict demands: every request answered exactly once
/// (success or *typed* error — no hangs past the reply grace, no raw
/// transport failures), all shards healthy again after the storm, at
/// least one injected panic observed with a matching restart, and every
/// successful reply — including fresh post-recovery probes — bitwise
/// equal to a direct-engine oracle.  Requests are sized to each route's
/// largest ladder block so service and oracle execute identical GEMM
/// shapes, making bitwise comparison meaningful.
fn faults(registry: &Registry, opts: &ServeOpts) -> Result<Json> {
    const CLIENTS: usize = 4;
    const MEAN_GAP_S: f64 = 1.2e-3;
    /// A reply later than this counts as a hang, not an error.
    const REPLY_GRACE: Duration = Duration::from_secs(3);
    /// Floor per client so every shard's arrival counter passes the
    /// fault-plan horizon even under short CI windows.
    const MIN_SENT: u64 = 150;
    const TYPED_KINDS: [&str; 3] = ["shard_failed", "overloaded", "busy"];

    let routes: Vec<Route> =
        route_table(registry).into_iter().filter(|r| r.key.mode == "exact").collect();
    ensure!(!routes.is_empty(), "no exact routes in the manifest");
    let shards = if opts.shards > 0 { opts.shards } else { 2 };
    let plan = FaultPlan::seeded(opts.seed, 96);
    let (inj_panics, inj_stalls, inj_drops) = plan.counts();
    let cfg = ServiceConfig {
        shards,
        seed: opts.seed,
        queue_capacity: 256,
        restart_backoff: Duration::from_millis(5),
        faults: Some(std::sync::Arc::new(plan)),
        ..ServiceConfig::default()
    };
    let svc = std::sync::Arc::new(Service::start(registry.clone(), cfg)?);
    warmup(&svc, &routes)?;
    // Largest ladder block per route: the request size every client uses.
    let route_n: Vec<usize> = routes
        .iter()
        .map(|r| Ok(*svc.router().batch_sizes(&r.key)?.last().unwrap()))
        .collect::<Result<_>>()?;
    let server = Server::start_with(
        svc.clone(),
        "127.0.0.1:0",
        ServerConfig { read_timeout: REPLY_GRACE, write_timeout: REPLY_GRACE, ..Default::default() },
    )?;
    let addr = server.addr();

    let t0 = Instant::now();
    let outs: Vec<FaultClientOut> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let routes = &routes;
                let route_n = &route_n;
                s.spawn(move || {
                    let mut rng = Rng::new(opts.seed ^ 0xFA17u64.wrapping_mul(c as u64 + 1));
                    let mut out = FaultClientOut::default();
                    let client_cfg = ClientConfig { read_timeout: REPLY_GRACE, ..Default::default() };
                    let Ok(mut client) = Client::connect_with(addr, client_cfg) else {
                        out.untyped_errors += 1;
                        return out;
                    };
                    let end = Instant::now() + opts.duration;
                    while Instant::now() < end || out.sent < MIN_SENT {
                        let gap = -MEAN_GAP_S * (1.0 - rng.uniform()).ln();
                        std::thread::sleep(Duration::from_secs_f64(gap));
                        let ri = rng.below(routes.len());
                        let (route, n) = (&routes[ri], route_n[ri]);
                        let mut pts = vec![0.0f32; n * route.dim];
                        rng.fill_normal_f32(&mut pts);
                        let deadline_ms = rng.uniform_in(2.0, 8.0);
                        out.sent += 1;
                        let t = Instant::now();
                        let got = client.eval_with_deadline(
                            &route.key.op,
                            &route.key.method,
                            &route.key.mode,
                            route.dim,
                            &pts,
                            Some(deadline_ms),
                        );
                        match got {
                            Ok((f0, op)) => {
                                out.latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
                                out.points += n as u64;
                                out.samples.push(Sample { route: ri, points: pts, f0, op });
                            }
                            Err(e) => {
                                if t.elapsed() >= REPLY_GRACE {
                                    out.hangs += 1;
                                } else if let Some(se) = e.downcast_ref::<ServerError>() {
                                    *out.error_kinds.entry(se.kind.clone()).or_insert(0) += 1;
                                    if TYPED_KINDS.contains(&se.kind.as_str()) {
                                        out.typed_errors += 1;
                                    } else {
                                        out.untyped_errors += 1;
                                    }
                                } else {
                                    out.untyped_errors += 1;
                                }
                            }
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let server_m = server_side(svc.metrics());

    // The storm is over (all fault indices sit below the horizon every
    // shard's arrival counter has passed); wait for supervised restarts
    // to settle, then probe each route through a fresh connection.
    let rec_deadline = Instant::now() + Duration::from_secs(5);
    while !svc.health().all_healthy() && Instant::now() < rec_deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let recovered = svc.health().all_healthy();
    let mut oracle = Oracle::new(registry, opts.seed)?;
    let mut recovery_failures = 0u64;
    if recovered {
        let mut client = Client::connect(addr)?;
        let mut rec_rng = Rng::new(opts.seed ^ 0x7EC0);
        for (ri, route) in routes.iter().enumerate() {
            let n = route_n[ri];
            let mut pts = vec![0.0f32; n * route.dim];
            rec_rng.fill_normal_f32(&mut pts);
            let bad = client
                .eval(&route.key.op, &route.key.method, &route.key.mode, route.dim, &pts)
                .and_then(|(f0, op)| {
                    oracle.check_bitwise(&route.key, route.dim, &pts, &f0, &op)
                });
            match bad {
                Ok(0) => {}
                _ => recovery_failures += 1,
            }
        }
    }
    let panics = svc.metrics().shard_panics();
    let restarts = svc.metrics().shard_restarts();
    server.stop();

    let mut agg = Agg::default();
    let mut sent = 0u64;
    let mut typed = 0u64;
    let mut untyped = 0u64;
    let mut hangs = 0u64;
    let mut error_kinds: BTreeMap<String, u64> = BTreeMap::new();
    for mut o in outs {
        sent += o.sent;
        typed += o.typed_errors;
        untyped += o.untyped_errors;
        hangs += o.hangs;
        agg.points += o.points;
        agg.latencies_ms.append(&mut o.latencies_ms);
        for (k, v) in o.error_kinds {
            *error_kinds.entry(k).or_insert(0) += v;
        }
        for s in o.samples {
            let r = &routes[s.route];
            agg.requests += 1;
            agg.oracle_checked += 1;
            if oracle.check_bitwise(&r.key, r.dim, &s.points, &s.f0, &s.op)? > 0 {
                agg.oracle_failures += 1;
            }
        }
    }
    agg.latencies_ms.sort_by(f64::total_cmp);
    agg.shed = typed;
    agg.errors = untyped + hangs;
    drop(svc);

    // Chaos-specific verdict: accounting closes (one outcome per
    // request), nothing untyped or hung, faults demonstrably fired and
    // the service demonstrably recovered to bitwise-identical replies.
    let ok = agg.oracle_failures == 0
        && untyped == 0
        && hangs == 0
        && recovered
        && recovery_failures == 0
        && panics >= 1
        && restarts >= 1
        && agg.requests + typed == sent;
    let extra = vec![
        ("sent", Json::num(sent as f64)),
        ("typed_errors", Json::num(typed as f64)),
        ("untyped_errors", Json::num(untyped as f64)),
        ("hangs", Json::num(hangs as f64)),
        (
            "error_kinds",
            Json::obj(
                error_kinds
                    .iter()
                    .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
                    .collect::<Vec<_>>(),
            ),
        ),
        ("injected_panics", Json::num(inj_panics as f64)),
        ("injected_stalls", Json::num(inj_stalls as f64)),
        ("injected_drops", Json::num(inj_drops as f64)),
        ("observed_panics", Json::num(panics as f64)),
        ("observed_restarts", Json::num(restarts as f64)),
        ("recovered", Json::Bool(recovered)),
        ("recovery_failures", Json::num(recovery_failures as f64)),
    ];
    let mut j = summary("faults", shards, wall, &agg, &server_m, extra);
    if let Json::Obj(m) = &mut j {
        m.insert("ok".into(), Json::Bool(ok));
    }
    Ok(j)
}

// ---------------------------------------------------------------------------
// Process-isolated driver
// ---------------------------------------------------------------------------

/// Spawn the binary once per scenario (`bench serve --scenario <name>
/// --json`), collect and validate each summary line.  Returns the lines
/// joined with newlines plus the overall verdict; a scenario that fails
/// its own checks turns the verdict false but does not stop the suite.
pub fn run_suite(
    scenarios: &[&str],
    opts: &ServeOpts,
    artifacts: &str,
    out_path: Option<&str>,
) -> Result<(String, bool)> {
    let bin = std::env::current_exe().context("locating the ctaylor binary")?;
    let mut lines = Vec::new();
    let mut all_ok = true;
    for (i, name) in scenarios.iter().enumerate() {
        eprintln!("[{}/{}] serve scenario {name}: {}", i + 1, scenarios.len(), describe(name));
        let out = std::process::Command::new(&bin)
            .args(["bench", "serve", "--scenario", name, "--json"])
            .arg(format!("--duration-ms={}", opts.duration.as_millis()))
            .arg(format!("--shards={}", opts.shards))
            .arg(format!("--seed={}", opts.seed))
            .arg(format!("--artifacts={artifacts}"))
            .output()
            .with_context(|| format!("spawning scenario {name}"))?;
        let stdout = String::from_utf8_lossy(&out.stdout);
        let Some(line) = stdout.lines().rev().find(|l| !l.trim().is_empty()) else {
            bail!(
                "scenario {name} produced no summary ({}): {}",
                out.status,
                String::from_utf8_lossy(&out.stderr)
            );
        };
        let j = json::parse(line).map_err(|e| anyhow!("scenario {name}: bad summary: {e}"))?;
        ensure!(
            j.get_str("format") == Some(FORMAT),
            "scenario {name}: summary is not {FORMAT}: {line}"
        );
        let ok = j.get("ok").and_then(Json::as_bool) == Some(true) && out.status.success();
        if !ok {
            eprintln!("scenario {name} FAILED: {line}");
        }
        all_ok &= ok;
        lines.push(line.to_string());
    }
    let joined = lines.join("\n");
    if let Some(p) = out_path {
        std::fs::write(p, joined.clone() + "\n").with_context(|| format!("writing {p}"))?;
    }
    Ok((joined, all_ok))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(pct(&v, 0.0), 1.0);
        assert_eq!(pct(&v, 1.0), 100.0);
        assert!((pct(&v, 0.5) - 50.0).abs() <= 1.0);
        assert!(pct(&v, 0.99) >= 99.0);
        assert_eq!(pct(&[], 0.5), 0.0);
    }

    #[test]
    fn close_is_relative() {
        assert!(close(1.00001, 1.0));
        assert!(!close(1.01, 1.0));
        assert!(close(1000.05, 1000.0));
        assert!(!close(f32::NAN, 1.0));
    }

    #[test]
    fn every_scenario_has_a_description() {
        for s in SCENARIOS {
            assert_ne!(describe(s), "unknown scenario", "{s}");
        }
        assert_eq!(describe("nope"), "unknown scenario");
    }

    #[test]
    fn summary_carries_the_format_and_ok_verdict() {
        let agg = Agg {
            latencies_ms: vec![1.0, 2.0, 3.0],
            requests: 3,
            points: 30,
            shed: 1,
            errors: 0,
            oracle_checked: 3,
            oracle_failures: 0,
        };
        let server = ServerSide { queue_p99_ms: 0.5, exec_p99_ms: 1.0, padding_ratio: 0.1 };
        let j = summary("baseline", 2, 1.0, &agg, &server, Vec::new());
        assert_eq!(j.get_str("format"), Some(FORMAT));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get_f64("throughput_pts_s"), Some(30.0));
        assert_eq!(j.get_f64("shed"), Some(1.0));
        let line = json::to_string(&j);
        assert!(!line.contains('\n'), "summary must be a single line");

        let bad = Agg { oracle_failures: 1, ..Default::default() };
        let j = summary("chaos", 2, 1.0, &bad, &server, Vec::new());
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn baseline_scenario_end_to_end_small() {
        // In-process smoke of the full scenario path on the builtin
        // registry: short window, still oracle-checked.
        let reg = Registry::builtin();
        let opts = ServeOpts {
            duration: Duration::from_millis(120),
            shards: 1,
            seed: 7,
        };
        let j = run_scenario("baseline", &reg, &opts).unwrap();
        assert_eq!(j.get_str("format"), Some(FORMAT));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{}", json::to_string(&j));
        assert!(j.get_f64("requests").unwrap() >= 1.0);
        assert_eq!(j.get_f64("oracle_failures"), Some(0.0));
    }
}
