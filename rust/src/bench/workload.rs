//! Workload generation for benchmark runs: deterministic parameters,
//! inputs, σ matrices and stochastic directions per artifact, packaged as
//! a named [`Workload`] that attaches to an API request builder.

use crate::api::{EvalRequest, OperatorHandle};
use crate::runtime::{ArtifactMeta, HostTensor};
use crate::util::prng::Rng;

/// Deterministic Glorot parameters for an artifact's network shape.
/// Drawing from `Rng::new(seed)` matches `Mlp::init(&mut Rng::new(seed))`
/// bitwise, which the oracle tests rely on.
pub fn theta_for(meta: &ArtifactMeta, seed: u64) -> HostTensor {
    meta.glorot_theta(&mut Rng::new(seed))
}

/// Standard-normal input batch `[B, D]`.
pub fn input_for(meta: &ArtifactMeta, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed ^ 0x5eed);
    let mut x = vec![0.0f32; meta.batch * meta.dim];
    rng.fill_normal_f32(&mut x);
    HostTensor::new(vec![meta.batch, meta.dim], x)
}

/// The paper's weighted-Laplacian coefficient: full-rank diagonal σ.
pub fn sigma_for(meta: &ArtifactMeta, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed ^ 0x51617);
    let d = meta.dim;
    let mut s = vec![0.0f32; d * d];
    for i in 0..d {
        s[i * d + i] = rng.uniform_in(0.5, 1.5) as f32;
    }
    HostTensor::new(vec![d, d], s)
}

/// Directions `[S, D]` for stochastic estimators: Rademacher for traces,
/// Gaussian for the 4th-order biharmonic (Isserlis unbiasedness).  The
/// weighted Laplacian gets σ-premultiplied dirs — aot.py's artifact
/// contract keeps the compiled executable shape-uniform (paper eq. 8a).
pub fn dirs_for(meta: &ArtifactMeta, seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed ^ 0xd15);
    let mut d = vec![0.0f32; meta.samples * meta.dim];
    if meta.op == "biharmonic" {
        rng.fill_normal_f32(&mut d);
    } else {
        rng.fill_rademacher_f32(&mut d);
    }
    if meta.op == "weighted_laplacian" {
        let sigma = sigma_for(meta, seed);
        d = crate::operators::stochastic::premultiply_sigma_f32(
            &d, &sigma.data, meta.dim, meta.dim,
        );
    }
    HostTensor::new(vec![meta.samples, meta.dim], d)
}

/// The named inputs one artifact's route consumes: θ, x, then σ (exact
/// weighted Laplacian) or dirs (stochastic estimators).
#[derive(Debug, Clone)]
pub struct Workload {
    pub theta: HostTensor,
    pub x: HostTensor,
    pub sigma: Option<HostTensor>,
    pub dirs: Option<HostTensor>,
}

impl Workload {
    /// Attach this workload to a handle's request builder in named form.
    pub fn request<'a>(&'a self, handle: &'a OperatorHandle) -> EvalRequest<'a> {
        let mut req = handle.eval().theta(&self.theta).x(&self.x);
        if let Some(s) = &self.sigma {
            req = req.sigma(s);
        }
        if let Some(d) = &self.dirs {
            req = req.directions(d);
        }
        req
    }
}

/// Deterministic named inputs for one artifact.
pub fn workload_for(meta: &ArtifactMeta, seed: u64) -> Workload {
    let sigma = if meta.op == "weighted_laplacian" && meta.mode == "exact" {
        Some(sigma_for(meta, seed))
    } else {
        None
    };
    let dirs = if meta.mode == "stochastic" { Some(dirs_for(meta, seed)) } else { None };
    Workload { theta: theta_for(meta, seed), x: input_for(meta, seed), sigma, dirs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TensorSpec;

    fn fake_meta() -> ArtifactMeta {
        ArtifactMeta {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            op: "laplacian".into(),
            method: "collapsed".into(),
            mode: "exact".into(),
            dim: 3,
            widths: vec![4, 1],
            batch: 2,
            samples: 0,
            theta_len: 3 * 4 + 4 + 4 + 1,
            layer_dims: vec![(3, 4), (4, 1)],
            variant: "plain".into(),
            inputs: vec![],
            outputs: vec![TensorSpec { name: "f0".into(), shape: vec![2, 1], dtype: "f32".into() }],
        }
    }

    #[test]
    fn deterministic_and_correctly_shaped() {
        let m = fake_meta();
        let t1 = theta_for(&m, 7);
        let t2 = theta_for(&m, 7);
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), m.theta_len);
        let x = input_for(&m, 7);
        assert_eq!(x.shape, vec![2, 3]);
        // biases zero
        assert_eq!(t1.data[12..16], [0.0; 4]);
    }

    #[test]
    fn sigma_is_diagonal_full_rank() {
        let m = fake_meta();
        let s = sigma_for(&m, 1);
        for i in 0..3 {
            for j in 0..3 {
                let v = s.data[i * 3 + j];
                if i == j {
                    assert!(v >= 0.5 && v <= 1.5);
                } else {
                    assert_eq!(v, 0.0);
                }
            }
        }
    }
}
