//! Benchmark harness: regenerate every table and figure of the paper's
//! evaluation on this substrate (DESIGN.md §4 experiment index).
//!
//! Each `run_*` function measures, renders a paper-style table to stdout
//! and persists raw numbers under `bench_results/` so EXPERIMENTS.md can
//! cite them.  Absolute numbers differ from the paper's RTX 6000; the
//! claims under test are the *ratios* (who wins, by what factor).

pub mod barometer;
pub mod report;
pub mod serve;
pub mod sweep;
pub mod workload;

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::api::Engine;
use crate::coordinator::{RouteKey, Service, ServiceConfig};
use crate::runtime::Registry;
use crate::taylor::count;
use crate::util::json::Json;
use crate::util::prng::Rng;
use report::{jobj, save_json, save_text, table, with_ratio};
use sweep::{run_sweep, Sweep, MEM_COUNT_MODEL, MEM_GRAPH_HLO, MEM_HLO};

pub const METHODS: [&str; 3] = ["nested", "standard", "collapsed"];
pub const OPS: [&str; 3] = ["laplacian", "weighted_laplacian", "biharmonic"];

fn results_dir() -> PathBuf {
    std::env::var("CTAYLOR_RESULTS").map(PathBuf::from).unwrap_or_else(|_| "bench_results".into())
}

/// Fig. 1: runtime vs batch size for the three implementations — the
/// exact Laplacian plus the composed Helmholtz-type spec, so the smoke
/// bench tracks the single-push composed-operator path over time.
pub fn run_fig1(registry: &Registry, reps: usize) -> Result<String> {
    let engine = Engine::builder().registry(registry.clone()).build()?;
    let mut rows = Vec::new();
    let mut sweeps = Vec::new();
    for op in ["laplacian", "helmholtz"] {
        for method in METHODS {
            let s = run_sweep(&engine, op, method, "exact", reps, 1)?;
            for p in &s.points {
                rows.push(vec![
                    op.to_string(),
                    method.to_string(),
                    format!("{}", p.x as usize),
                    format!("{:.3}", p.time_s * 1e3),
                ]);
            }
            sweeps.push(s);
        }
    }
    let mut out =
        String::from("# Fig. 1 — exact Laplacian & Helmholtz-spec runtime vs batch (ms)\n\n");
    out.push_str(&table(&["op", "method", "batch", "time [ms]"], &rows));
    out.push_str("\nper-datum slope [ms]:\n");
    for chunk in sweeps.chunks(METHODS.len()) {
        let base = chunk[0].ms_per_x();
        for s in chunk {
            out.push_str(&format!(
                "  {:<18} {:<10} {}\n",
                s.op,
                s.method,
                with_ratio(s.ms_per_x(), base)
            ));
        }
    }
    let j = Json::arr(sweeps.iter().map(sweep_json));
    save_json(&results_dir(), "fig1", &j)?;
    save_text(&results_dir(), "fig1", &out)?;
    Ok(out)
}

fn sweep_json(s: &Sweep) -> Json {
    Json::obj(vec![
        ("op", Json::str(&s.op)),
        ("method", Json::str(&s.method)),
        ("mode", Json::str(&s.mode)),
        ("mem_source", Json::str(s.mem_source())),
        ("ms_per_x", Json::num(s.ms_per_x())),
        ("mib_diff_per_x", Json::num(s.mib_diff_per_x())),
        ("mib_nondiff_per_x", Json::num(s.mib_nondiff_per_x())),
        (
            "points",
            Json::arr(s.points.iter().map(|p| {
                jobj(&[
                    ("x", p.x),
                    ("time_s", p.time_s),
                    ("mem_diff", p.mem_diff),
                    ("mem_nondiff", p.mem_nondiff),
                    ("flops", p.flops),
                ])
            })),
        ),
    ])
}

/// Fig. 5 + Table 1: the full grid — per-datum (exact) and per-sample
/// (stochastic) slopes of runtime and both memory proxies, for all three
/// operators × three implementations.
pub fn run_fig5_table1(registry: &Registry, reps: usize) -> Result<String> {
    let engine = Engine::builder().registry(registry.clone()).build()?;
    let mut all: Vec<Sweep> = Vec::new();
    for mode in ["exact", "stochastic"] {
        for op in OPS {
            for method in METHODS {
                all.push(run_sweep(&engine, op, method, mode, reps, 2)?);
            }
        }
    }
    fn get<'a>(all: &'a [Sweep], op: &str, method: &str, mode: &str) -> &'a Sweep {
        all.iter()
            .find(|s| s.op == op && s.method == method && s.mode == mode)
            .unwrap()
    }

    let mut out = String::from(
        "# Table 1 — per-datum (exact) / per-sample (stochastic) slopes\n\n",
    );
    for mode in ["exact", "stochastic"] {
        for (metric, f) in [
            ("Time [ms]", &(|s: &Sweep| s.ms_per_x()) as &dyn Fn(&Sweep) -> f64),
            ("Mem diff [MiB]", &|s: &Sweep| s.mib_diff_per_x()),
            ("Mem non-diff [MiB]", &|s: &Sweep| s.mib_nondiff_per_x()),
        ] {
            let mut rows = Vec::new();
            for method in METHODS {
                let mut row = vec![mode.to_string(), metric.to_string(), method.to_string()];
                for op in OPS {
                    let s = get(&all, op, method, mode);
                    let base = f(get(&all, op, "nested", mode));
                    row.push(with_ratio(f(s), base));
                }
                rows.push(row);
            }
            out.push_str(&table(
                &[
                    "mode",
                    "metric",
                    "implementation",
                    "Laplacian",
                    "Weighted Laplacian",
                    "Biharmonic",
                ],
                &rows,
            ));
            out.push('\n');
        }
    }
    if all.iter().any(|s| s.mem_source() == MEM_COUNT_MODEL) {
        out.push_str(
            "note: memory rows use the analytic propagated-vector proxy for artifacts \
             without HLO on disk (count-model), not a measurement.\n",
        );
    }
    let j = Json::arr(all.iter().map(sweep_json));
    save_json(&results_dir(), "fig5_table1", &j)?;
    save_text(&results_dir(), "table1", &out)?;
    Ok(out)
}

/// Table F2: theoretical Δ-vector ratios vs the measured slope ratios.
pub fn run_table_f2(registry: &Registry, reps: usize) -> Result<String> {
    let engine = Engine::builder().registry(registry.clone()).build()?;
    // Dims come from the manifest (preset-dependent).
    let lap_dim = registry
        .select("laplacian", "collapsed", "exact")
        .first()
        .map(|a| a.dim)
        .unwrap_or(16);
    let bih_dim = registry
        .select("biharmonic", "collapsed", "exact")
        .first()
        .map(|a| a.dim)
        .unwrap_or(5);

    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let op_dims =
        [("laplacian", lap_dim), ("weighted_laplacian", lap_dim), ("biharmonic", bih_dim)];
    for mode in ["exact", "stochastic"] {
        for (op, dim) in op_dims {
            let theory = match (mode, op) {
                ("exact", "biharmonic") => count::exact_ratio_biharmonic(dim),
                ("exact", _) => count::exact_ratio_laplacian(dim),
                (_, "biharmonic") => count::stochastic_ratio(4),
                _ => count::stochastic_ratio(2),
            };
            let s_std = run_sweep(&engine, op, "standard", mode, reps, 3)?;
            let s_col = run_sweep(&engine, op, "collapsed", mode, reps, 3)?;
            let time_ratio = s_col.ms_per_x() / s_std.ms_per_x();
            let mem_ratio = s_col.mib_diff_per_x() / s_std.mib_diff_per_x();
            let mem_source = match (s_std.mem_source(), s_col.mem_source()) {
                (MEM_HLO, MEM_HLO) => MEM_HLO,
                (a, b) if a != MEM_COUNT_MODEL && b != MEM_COUNT_MODEL => MEM_GRAPH_HLO,
                _ => MEM_COUNT_MODEL,
            };
            rows.push(vec![
                mode.to_string(),
                format!("{op} (D={dim})"),
                format!("{theory:.2}"),
                format!("{time_ratio:.2}"),
                format!("{mem_ratio:.2} [{mem_source}]"),
            ]);
            json_rows.push(Json::obj(vec![
                ("mode", Json::str(mode)),
                ("op", Json::str(op)),
                ("dim", Json::num(dim as f64)),
                ("theory", Json::num(theory)),
                ("time_ratio", Json::num(time_ratio)),
                ("mem_ratio", Json::num(mem_ratio)),
                ("mem_source", Json::str(mem_source)),
            ]));
        }
    }
    let mut out =
        String::from("# Table F2 — theoretical vs empirical collapsed/standard ratios\n\n");
    out.push_str(&table(
        &["mode", "operator", "theory Δvec ratio", "empirical time", "empirical mem"],
        &rows,
    ));
    out.push_str(
        "\nmem provenance: [hlo] analyzes on-disk AOT text; [graph-hlo] analyzes HLO emitted\n\
         from the route's traced+collapsed graph (a real instruction-level analysis);\n\
         [count-model] restates the analytic theory column rather than measuring it.\n",
    );
    save_json(&results_dir(), "table_f2", &Json::Arr(json_rows))?;
    save_text(&results_dir(), "table_f2", &out)?;
    Ok(out)
}

/// Fig. G9 + Table G3: the Laplacian column plus the biharmonic computed
/// as nested Laplacians, per available method.
pub fn run_figg9_tableg3(registry: &Registry, reps: usize) -> Result<String> {
    let engine = Engine::builder().registry(registry.clone()).build()?;
    let mut out = String::from("# Table G3 — Laplacian & biharmonic-as-nested-Laplacians\n\n");
    let mut all = Vec::new();
    for op in ["laplacian", "biharl"] {
        let mut rows = Vec::new();
        let mut base_t = None;
        let mut base_m = None;
        for method in METHODS {
            if registry.select(op, method, "exact").len() < 2 {
                continue; // method not compiled for this op
            }
            let s = run_sweep(&engine, op, method, "exact", reps, 4)?;
            let bt = *base_t.get_or_insert(s.ms_per_x());
            let bm = *base_m.get_or_insert(s.mib_diff_per_x());
            rows.push(vec![
                method.to_string(),
                with_ratio(s.ms_per_x(), bt),
                with_ratio(s.mib_diff_per_x(), bm),
                format!("{:.4}", s.mib_nondiff_per_x()),
            ]);
            all.push(s);
        }
        out.push_str(&format!("## {op}\n"));
        out.push_str(&table(
            &[
                "implementation",
                "time [ms/datum]",
                "mem diff [MiB/datum]",
                "mem non-diff [MiB/datum]",
            ],
            &rows,
        ));
        out.push('\n');
    }
    if all.iter().any(|s| s.mem_source() == MEM_COUNT_MODEL) {
        out.push_str(
            "note: memory rows use the analytic propagated-vector proxy for artifacts \
             without HLO on disk (count-model), not a measurement.\n",
        );
    }
    let j = Json::arr(all.iter().map(sweep_json));
    save_json(&results_dir(), "figg9_tableg3", &j)?;
    save_text(&results_dir(), "table_g3", &out)?;
    Ok(out)
}

/// Native-engine ablation: wallclock of the three methods on the in-Rust
/// engines, the single-push vs per-family biharmonic plan, plus the §C
/// graph-rewrite effect (propagation cost + FLOPs).
pub fn run_native_ablation(reps: usize) -> Result<String> {
    use crate::mlp::Mlp;
    use crate::operators::{plan, OperatorSpec};
    use crate::taylor::interp;
    use crate::taylor::jet::Collapse;
    use crate::taylor::rewrite::collapse;
    use crate::taylor::tensor::Tensor;
    use crate::taylor::trace::{basis_dirs, build_mlp_jet_std, TAGGED_SLOTS};
    use crate::util::stats::time_fn;

    let mut rng = Rng::new(9);
    let dim = 8;
    let batch = 8;
    let mlp = Mlp::init(&mut rng, dim, &[64, 64, 48, 48, 1], batch);
    let x = mlp.random_input(&mut rng);

    let t_nested = time_fn(
        || {
            std::hint::black_box(crate::nested::laplacian(&mlp, &x, None, 1.0));
        },
        reps,
    );
    let t_std = time_fn(
        || {
            std::hint::black_box(crate::operators::laplacian_native(&mlp, &x, Collapse::Standard));
        },
        reps,
    );
    let t_col = time_fn(
        || {
            std::hint::black_box(crate::operators::laplacian_native(&mlp, &x, Collapse::Collapsed));
        },
        reps,
    );

    // Single-push vs per-family biharmonic: the compiled OperatorSpec
    // stacks the three Griewank families into one direction bundle; the
    // pre-plan engine pushed one 4-jet per family (three MLP traversals,
    // three derivative evaluations per node).
    let bdim = 4;
    let bmlp = Mlp::init(&mut rng, bdim, &[32, 32, 1], batch);
    let bx = bmlp.random_input(&mut rng);
    let bspec = OperatorSpec::biharmonic(bdim);
    let bplan = bspec.compile();
    let t_bih_single = time_fn(
        || {
            std::hint::black_box(plan::apply(&bmlp, &bx, &bplan, Collapse::Collapsed));
        },
        reps,
    );
    let family_plans: Vec<_> = bspec
        .families
        .iter()
        .map(|fam| {
            OperatorSpec { name: "family".into(), c0: 0.0, families: vec![fam.clone()] }.compile()
        })
        .collect();
    let per_family_sum = || {
        let mut total: Option<Tensor> = None;
        for p in &family_plans {
            let (_, s) = plan::apply(&bmlp, &bx, p, Collapse::Collapsed);
            total = Some(match total {
                Some(t) => t.add(&s),
                None => s,
            });
        }
        total.expect("three families")
    };
    let t_bih_per_family = time_fn(
        || {
            std::hint::black_box(per_family_sum());
        },
        reps,
    );
    // Both paths must compute the same operator.
    let single = plan::apply(&bmlp, &bx, &bplan, Collapse::Collapsed).1;
    let bih_dev = single.max_abs_diff(&per_family_sum());

    // Graph rewrite ablation
    let g = build_mlp_jet_std(&mlp, 2, dim);
    let c = collapse(&g, TAGGED_SLOTS, dim);
    let shapes = vec![vec![batch, dim], vec![dim, batch, dim]];
    let flops_std = interp::flops(&g, &shapes)?;
    let flops_col = interp::flops(&c, &shapes)?;
    let cost_std = g.propagation_cost(TAGGED_SLOTS, dim);
    let cost_col = c.propagation_cost(TAGGED_SLOTS, dim);

    let dirs = basis_dirs(dim, batch);
    let t_graph_std = time_fn(
        || {
            std::hint::black_box(interp::eval(&g, &[x.clone(), dirs.clone()]).unwrap());
        },
        reps,
    );
    let t_graph_col = time_fn(
        || {
            std::hint::black_box(interp::eval(&c, &[x.clone(), dirs.clone()]).unwrap());
        },
        reps,
    );

    let mut out = String::from("# Native-engine ablation (Laplacian, D=8, B=8)\n\n");
    let engine_row = |name: &str, t: f64| {
        vec![name.to_string(), format!("{:.3}", t * 1e3), "-".into(), "-".into()]
    };
    let rows = vec![
        engine_row("nested 1st-order (engine)", t_nested.min),
        engine_row("standard Taylor (engine)", t_std.min),
        engine_row("collapsed Taylor (engine)", t_col.min),
        vec![
            "standard Taylor (graph)".into(),
            format!("{:.3}", t_graph_std.min * 1e3),
            format!("{flops_std}"),
            format!("{cost_std}"),
        ],
        vec![
            "collapsed via §C rewrites".into(),
            format!("{:.3}", t_graph_col.min * 1e3),
            format!("{flops_col}"),
            format!("{cost_col}"),
        ],
    ];
    out.push_str(&table(&["implementation", "time [ms]", "flops", "propagation cost"], &rows));
    out.push_str(&format!(
        "\nrewrite effect: flops x{:.2}, propagation cost x{:.2}\n",
        flops_col as f64 / flops_std as f64,
        cost_col as f64 / cost_std as f64
    ));
    out.push_str(&format!(
        "\n# Biharmonic plan (D={bdim}, B={batch}, collapsed): single stacked push \
         vs per-family\n\nsingle push   {:.3} ms\nper-family    {:.3} ms (3 pushes)\n",
        t_bih_single.min * 1e3,
        t_bih_per_family.min * 1e3,
    ));
    out.push_str(&format!(
        "speedup x{:.2}, max |Δ| = {bih_dev:.2e}\n",
        t_bih_per_family.min / t_bih_single.min.max(1e-12),
    ));
    save_text(&results_dir(), "native_ablation", &out)?;
    save_json(
        &results_dir(),
        "native_ablation",
        &jobj(&[
            ("nested_ms", t_nested.min * 1e3),
            ("standard_ms", t_std.min * 1e3),
            ("collapsed_ms", t_col.min * 1e3),
            ("graph_std_ms", t_graph_std.min * 1e3),
            ("graph_col_ms", t_graph_col.min * 1e3),
            ("flops_std", flops_std as f64),
            ("flops_col", flops_col as f64),
            ("cost_std", cost_std as f64),
            ("cost_col", cost_col as f64),
            ("biharmonic_single_push_ms", t_bih_single.min * 1e3),
            ("biharmonic_per_family_ms", t_bih_per_family.min * 1e3),
            ("biharmonic_push_dev", bih_dev),
        ]),
    )?;
    Ok(out)
}

/// Graph-compiler ablation on the fig1 workload (laplacian D=16, 32-32-1):
/// the standard trace and the §C-collapsed graph through the reference
/// interpreter vs the buffer-planned VM, against the jet engine — the
/// perf trajectory of the compiler win.
pub fn run_graph_ablation(reps: usize) -> Result<String> {
    use crate::mlp::Mlp;
    use crate::operators::{plan, OperatorSpec};
    use crate::taylor::jet::Collapse;
    use crate::taylor::rewrite::collapse;
    use crate::taylor::trace::{build_plan_jet_std, TAGGED_SLOTS};
    use crate::taylor::{interp, program};
    use crate::util::stats::time_fn;

    // Mirrors the builtin fig1 laplacian artifacts (D = 16, 32-32-1).
    let (dim, batch) = (16, 8);
    let mut rng = Rng::new(17);
    let mlp = Mlp::init(&mut rng, dim, &[32, 32, 1], batch);
    let x = mlp.random_input(&mut rng);
    let spec = OperatorSpec::laplacian(dim);
    let oplan = spec.compile();
    let num_dirs = oplan.dirs.shape[0];

    let g_std = build_plan_jet_std(&mlp, &oplan, batch);
    let g_col = collapse(&g_std, TAGGED_SLOTS, num_dirs);
    let shapes = vec![vec![batch, dim], vec![num_dirs, batch, dim]];
    let p_std = program::compile(&g_std, &shapes)?;
    let p_col = program::compile(&g_col, &shapes)?;

    // Directions broadcast over the batch, exactly as the runtime feeds
    // the VM.
    let dirs = oplan.dirs.broadcast_rows(batch);
    let inputs = [x.clone(), dirs];

    // All five paths must agree before timing anything.
    let oracle = plan::apply(&mlp, &x, &oplan, Collapse::Collapsed);
    let scale = oracle.1.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for out in [
        interp::eval(&g_std, &inputs)?,
        interp::eval(&g_col, &inputs)?,
        p_std.execute(&inputs)?,
        p_col.execute(&inputs)?,
    ] {
        anyhow::ensure!(out[0].max_abs_diff(&oracle.0) < 1e-10, "f0 deviates");
        anyhow::ensure!(out[1].max_abs_diff(&oracle.1) < 1e-10 * scale, "operator deviates");
    }

    let t_interp_std = time_fn(
        || {
            std::hint::black_box(interp::eval(&g_std, &inputs).unwrap());
        },
        reps,
    );
    let t_interp_col = time_fn(
        || {
            std::hint::black_box(interp::eval(&g_col, &inputs).unwrap());
        },
        reps,
    );
    let t_vm_std = time_fn(
        || {
            std::hint::black_box(p_std.execute(&inputs).unwrap());
        },
        reps,
    );
    let t_vm_col = time_fn(
        || {
            std::hint::black_box(p_col.execute(&inputs).unwrap());
        },
        reps,
    );
    let t_jet = time_fn(
        || {
            std::hint::black_box(plan::apply(&mlp, &x, &oplan, Collapse::Collapsed));
        },
        reps,
    );

    let cost_std = g_std.propagation_cost(TAGGED_SLOTS, num_dirs);
    let cost_col = g_col.propagation_cost(TAGGED_SLOTS, num_dirs);
    let mut out = String::from("# Graph-compiler ablation (laplacian, D=16, B=8, 32-32-1)\n\n");
    let rows = vec![
        vec![
            "interp std-trace".into(),
            format!("{:.3}", t_interp_std.min * 1e3),
            format!("{cost_std}"),
            "-".into(),
        ],
        vec![
            "interp §C-collapsed".into(),
            format!("{:.3}", t_interp_col.min * 1e3),
            format!("{cost_col}"),
            "-".into(),
        ],
        vec![
            "VM std-trace".into(),
            format!("{:.3}", t_vm_std.min * 1e3),
            format!("{cost_std}"),
            format!("{} regs / {} instrs", p_std.num_regs(), p_std.instrs.len()),
        ],
        vec![
            "VM §C-collapsed".into(),
            format!("{:.3}", t_vm_col.min * 1e3),
            format!("{cost_col}"),
            format!("{} regs / {} instrs", p_col.num_regs(), p_col.instrs.len()),
        ],
        vec![
            "jet engine (oracle)".into(),
            format!("{:.3}", t_jet.min * 1e3),
            "-".into(),
            "-".into(),
        ],
    ];
    out.push_str(&table(&["executor", "time [ms]", "propagation cost", "buffer plan"], &rows));
    out.push_str(&format!(
        "\nVM-collapsed vs interp-collapsed: x{:.2}; vs jet engine: x{:.2}\n",
        t_interp_col.min / t_vm_col.min.max(1e-12),
        t_jet.min / t_vm_col.min.max(1e-12),
    ));
    save_text(&results_dir(), "graph_ablation", &out)?;
    save_json(
        &results_dir(),
        "graph_ablation",
        &jobj(&[
            ("interp_std_ms", t_interp_std.min * 1e3),
            ("interp_col_ms", t_interp_col.min * 1e3),
            ("vm_std_ms", t_vm_std.min * 1e3),
            ("vm_col_ms", t_vm_col.min * 1e3),
            ("jet_ms", t_jet.min * 1e3),
            ("cost_std", cost_std as f64),
            ("cost_col", cost_col as f64),
            ("vm_col_regs", p_col.num_regs() as f64),
            ("vm_col_instrs", p_col.instrs.len() as f64),
            ("vm_col_flops", p_col.flops as f64),
            ("vm_std_flops", p_std.flops as f64),
            ("vm_col_arena_bytes", p_col.arena_bytes() as f64),
            ("vm_std_arena_bytes", p_std.arena_bytes() as f64),
        ]),
    )?;
    Ok(out)
}

/// GEMM micro-kernel sweep: the seed's branchy zero-skip triple loop vs
/// the tiled packed kernel, in GFLOP/s, across MLP-layer-like shapes plus
/// the 256³ headline — the kernel layer's perf trajectory.
pub fn run_kernel_micro(reps: usize) -> Result<String> {
    use crate::taylor::kernels;
    use crate::util::stats::time_fn;

    let shapes: [(usize, usize, usize); 5] =
        [(256, 256, 256), (512, 64, 64), (1024, 32, 32), (256, 16, 32), (4096, 32, 1)];
    let mut rng = Rng::new(33);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for (m, k, n) in shapes {
        let mut a = vec![0.0f64; m * k];
        let mut b = vec![0.0f64; k * n];
        for v in a.iter_mut() {
            *v = rng.normal();
        }
        for v in b.iter_mut() {
            *v = rng.normal();
        }
        let mut c = vec![0.0f64; m * n];
        let mut c_ref = vec![0.0f64; m * n];
        let flops = 2.0 * (m * k * n) as f64;
        let t_naive = time_fn(
            || {
                kernels::gemm_reference(m, k, n, &a, &b, &mut c_ref);
                std::hint::black_box(&c_ref);
            },
            reps,
        );
        let t_tiled = time_fn(
            || {
                kernels::gemm(m, k, n, &a, &b, &mut c);
                std::hint::black_box(&c);
            },
            reps,
        );
        // Faster must also mean equal.
        for (w, g) in c_ref.iter().zip(&c) {
            anyhow::ensure!(
                (w - g).abs() <= 1e-12 * (1.0 + w.abs()),
                "tiled GEMM deviates from the naive loop on {m}x{k}x{n}"
            );
        }
        // Single-precision rows: the same tiled seam, f32 storage with
        // pure-f32 vs f64-accumulating microkernels.
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let mut c32 = vec![0.0f32; m * n];
        let t_f32 = time_fn(
            || {
                kernels::gemm(m, k, n, &a32, &b32, &mut c32);
                std::hint::black_box(&c32);
            },
            reps,
        );
        let mut c32a = vec![0.0f32; m * n];
        let t_f32a = time_fn(
            || {
                kernels::gemm_with(m, k, n, &a32, &b32, &mut c32a, true);
                std::hint::black_box(&c32a);
            },
            reps,
        );
        for got in [&c32, &c32a] {
            for (w, g) in c_ref.iter().zip(got.iter()) {
                anyhow::ensure!(
                    (w - f64::from(*g)).abs() <= 1e-2 * (1.0 + w.abs()),
                    "f32 tiled GEMM drifts from the f64 loop on {m}x{k}x{n}"
                );
            }
        }
        let gf = |t: f64| flops / t.max(1e-12) / 1e9;
        let speedup = t_naive.min / t_tiled.min.max(1e-12);
        rows.push(vec![
            format!("{m}x{k}x{n}"),
            format!("{:.2}", gf(t_naive.min)),
            format!("{:.2}", gf(t_tiled.min)),
            format!("x{speedup:.2}"),
            format!("{:.2}", gf(t_f32.min)),
            format!("{:.2}", gf(t_f32a.min)),
        ]);
        json_rows.push(Json::obj(vec![
            ("m", Json::num(m as f64)),
            ("k", Json::num(k as f64)),
            ("n", Json::num(n as f64)),
            ("naive_gflops", Json::num(gf(t_naive.min))),
            ("tiled_gflops", Json::num(gf(t_tiled.min))),
            ("f32_gflops", Json::num(gf(t_f32.min))),
            ("f32a64_gflops", Json::num(gf(t_f32a.min))),
            ("speedup", Json::num(speedup)),
        ]));
    }
    let mut out = String::from("# Kernel micro-bench — naive/tiled f64 vs tiled f32 GEMM\n\n");
    let hdr = ["m x k x n", "naive f64", "tiled f64", "speedup", "tiled f32", "f32 acc64"];
    out.push_str(&table(&hdr, &rows));
    save_json(&results_dir(), "kernel_micro", &Json::Arr(json_rows))?;
    save_text(&results_dir(), "kernel_micro", &out)?;
    Ok(out)
}

/// Thread-scaling ablation: the serving path (cache hit → sharded VM) on
/// the largest fig1 batch, swept across executor counts 1/2/4/N.  Each
/// count gets its own engine (own pool, own program cache), so every row
/// measures the same steady state at a different parallelism.
pub fn run_thread_scaling(registry: &Registry, reps: usize) -> Result<String> {
    use crate::api::shard_count;
    use crate::util::stats::time_fn;

    let meta = registry
        .select("laplacian", "collapsed", "exact")
        .into_iter()
        .max_by_key(|a| a.batch)
        .ok_or_else(|| anyhow::anyhow!("no laplacian artifacts in the registry"))?
        .clone();
    let w = workload::workload_for(&meta, 7);
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts = vec![1usize, 2, 4, avail];
    counts.sort_unstable();
    counts.dedup();
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut base = None;
    for t in counts {
        let engine = Engine::builder().registry(registry.clone()).threads(t).build()?;
        let handle = engine.operator(&meta.name)?;
        // Compile outside the timed region (steady-state = cache hit).
        w.request(&handle).run()?;
        let timing = time_fn(
            || {
                w.request(&handle).run().expect("serving execution");
            },
            reps,
        );
        let b = *base.get_or_insert(timing.min);
        rows.push(vec![
            format!("{t}"),
            format!("{}", shard_count(meta.batch, t)),
            format!("{:.3}", timing.min * 1e3),
            format!("x{:.2}", b / timing.min.max(1e-12)),
        ]);
        json_rows.push(Json::obj(vec![
            ("threads", Json::num(t as f64)),
            ("shards", Json::num(shard_count(meta.batch, t) as f64)),
            ("ms", Json::num(timing.min * 1e3)),
            ("speedup_vs_1", Json::num(b / timing.min.max(1e-12))),
        ]));
    }
    let mut out = format!(
        "# Thread scaling — {} (B={}) through the sharded serving path\n\n",
        meta.name, meta.batch
    );
    out.push_str(&table(&["threads", "shards", "time [ms]", "speedup vs 1"], &rows));
    save_json(&results_dir(), "thread_scaling", &Json::Arr(json_rows))?;
    save_text(&results_dir(), "thread_scaling", &out)?;
    Ok(out)
}

/// The CI smoke bench: fig1 sweeps, the graph-compiler ablation, the GEMM
/// kernel micro-sweep and the thread-scaling ablation, combined into one
/// `smoke.json` so BENCH_smoke tracks the serving path, the compiler win
/// and the kernel/threading layer per PR (reusing the fig1 build — no
/// extra compile cost in the job).
pub fn run_smoke(registry: &Registry, reps: usize) -> Result<String> {
    let mut out = run_fig1(registry, reps)?;
    out.push('\n');
    out.push_str(&run_graph_ablation(reps.max(3))?);
    out.push('\n');
    out.push_str(&run_kernel_micro(reps.max(3))?);
    out.push('\n');
    out.push_str(&run_thread_scaling(registry, reps.max(3))?);
    let dir = results_dir();
    let load = |name: &str| report::load_json(&dir.join(format!("{name}.json")));
    save_json(
        &dir,
        "smoke",
        &Json::obj(vec![
            ("fig1", load("fig1")?),
            ("graph_ablation", load("graph_ablation")?),
            ("kernel_micro", load("kernel_micro")?),
            ("thread_scaling", load("thread_scaling")?),
        ]),
    )?;
    Ok(out)
}

/// Coordinator throughput/latency under concurrent load.
pub fn run_coordinator_bench(registry: Registry, n_requests: usize) -> Result<String> {
    let dim = registry
        .select("laplacian", "collapsed", "exact")
        .first()
        .map(|a| a.dim)
        .unwrap_or(16);
    let mut cfg = ServiceConfig::default();
    if let Ok(e) = std::env::var("CTAYLOR_EAGER") {
        cfg.eager_points = e.parse().unwrap_or(cfg.eager_points);
    }
    if let Ok(f) = std::env::var("CTAYLOR_DEADLINE_US") {
        cfg.default_deadline = std::time::Duration::from_micros(f.parse().unwrap_or(5000));
    }
    let svc = Service::start(registry, cfg)?;
    let route = RouteKey::new("laplacian", "collapsed", "exact");
    let mut rng = Rng::new(11);

    // Warmup: a 31-point request exercises every block size (16+8+4+2+1),
    // pulling all compiles out of the timed region (§Perf L3).
    svc.eval_blocking(route.clone(), vec![0.0f32; 31 * dim], dim)?;

    let t0 = Instant::now();
    let mut receivers = Vec::new();
    let mut total_points = 0usize;
    for _ in 0..n_requests {
        let n = 1 + rng.below(16);
        total_points += n;
        let mut pts = vec![0.0f32; n * dim];
        rng.fill_normal_f32(&mut pts);
        receivers.push(svc.submit(route.clone(), pts, dim)?);
    }
    for rx in receivers {
        rx.recv()??;
    }
    let wall = t0.elapsed().as_secs_f64();
    let summary = svc.metrics().summary();
    let out = format!(
        "# Coordinator throughput\n\nrequests={n_requests} points={total_points} wall={wall:.3}s \
         -> {:.0} points/s\n{summary}\n",
        total_points as f64 / wall,
    );
    save_text(&results_dir(), "coordinator", &out)?;
    svc.shutdown();
    Ok(out)
}
