//! Native MLP: the benchmark network for the in-Rust engines.
//!
//! Mirrors python/compile/model.py (tanh MLP, Glorot init, final layer
//! linear) so native and AOT results are directly comparable.

use crate::taylor::tensor::Tensor;
use crate::util::prng::Rng;

/// A tanh MLP with explicit (W, b) tensors.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub in_dim: usize,
    pub widths: Vec<usize>,
    pub layers: Vec<(Tensor, Tensor)>,
    /// Batch size used when building static graphs (constant zero seeds
    /// need a concrete shape).
    pub batch_hint: usize,
}

impl Mlp {
    /// Glorot-uniform init, zero biases (matches model.py).
    pub fn init(rng: &mut Rng, in_dim: usize, widths: &[usize], batch_hint: usize) -> Mlp {
        let mut layers = Vec::new();
        let mut prev = in_dim;
        for &w in widths {
            let mut wdata = vec![0.0f32; prev * w];
            rng.glorot_f32(prev, w, &mut wdata);
            let wt = Tensor::new(vec![prev, w], wdata.iter().map(|&v| v as f64).collect());
            let bt = Tensor::zeros(&[w]);
            layers.push((wt, bt));
            prev = w;
        }
        Mlp { in_dim, widths: widths.to_vec(), layers, batch_hint }
    }

    pub fn out_dim(&self) -> usize {
        *self.widths.last().unwrap()
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|(w, b)| w.len() + b.len()).sum()
    }

    /// Plain forward pass `[B, D] -> [B, C]`.
    pub fn apply(&self, x: &Tensor) -> Tensor {
        let n = self.layers.len();
        let mut h = x.clone();
        for (i, (w, b)) in self.layers.iter().enumerate() {
            h = h.matmul(w).add_bias(b);
            if i + 1 < n {
                h = h.map(f64::tanh);
            }
        }
        h
    }

    /// A batch_hint-sized standard-normal input.
    pub fn random_input(&self, rng: &mut Rng) -> Tensor {
        let n = self.batch_hint * self.in_dim;
        Tensor::new(
            vec![self.batch_hint, self.in_dim],
            (0..n).map(|_| rng.normal()).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_determinism() {
        let mut rng = Rng::new(0);
        let mlp = Mlp::init(&mut rng, 4, &[8, 3], 5);
        assert_eq!(mlp.num_params(), 4 * 8 + 8 + 8 * 3 + 3);
        let x = mlp.random_input(&mut rng);
        let y = mlp.apply(&x);
        assert_eq!(y.shape, vec![5, 3]);

        let mut rng2 = Rng::new(0);
        let mlp2 = Mlp::init(&mut rng2, 4, &[8, 3], 5);
        let x2 = mlp2.random_input(&mut rng2);
        assert!(mlp2.apply(&x2).max_abs_diff(&y) == 0.0);
    }

    #[test]
    fn output_is_finite() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::init(&mut rng, 2, &[4, 1], 1);
        let x = Tensor::new(vec![1, 2], vec![0.0, 0.0]);
        let y = mlp.apply(&x);
        assert!(y.data[0].is_finite());
    }
}
