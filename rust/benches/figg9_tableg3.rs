//! Regenerates paper Fig. G9 + Table G3: Laplacian and
//! biharmonic-as-nested-Laplacians.  `cargo bench --bench figg9_tableg3`.
fn main() -> anyhow::Result<()> {
    let reg = ctaylor::runtime::Registry::load_default()?;
    let reps = std::env::var("CTAYLOR_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    println!("{}", ctaylor::bench::run_figg9_tableg3(&reg, reps)?);
    Ok(())
}
