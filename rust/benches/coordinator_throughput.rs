//! Coordinator throughput/latency under concurrent load.
//! `cargo bench --bench coordinator_throughput`.
fn main() -> anyhow::Result<()> {
    let reg = ctaylor::runtime::Registry::load_default()?;
    let n = std::env::var("CTAYLOR_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    println!("{}", ctaylor::bench::run_coordinator_bench(reg, n)?);
    Ok(())
}
