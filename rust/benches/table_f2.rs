//! Regenerates paper Table F2: theoretical Δ-vector ratios vs measured
//! slope ratios.  `cargo bench --bench table_f2`.
fn main() -> anyhow::Result<()> {
    let reg = ctaylor::runtime::Registry::load_default()?;
    let reps = std::env::var("CTAYLOR_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    println!("{}", ctaylor::bench::run_table_f2(&reg, reps)?);
    Ok(())
}
