//! Regenerates paper Fig. 5 + Table 1: the full operator × method grid of
//! per-datum / per-sample slopes.  `cargo bench --bench fig5_table1`.
fn main() -> anyhow::Result<()> {
    let reg = ctaylor::runtime::Registry::load_default()?;
    let reps = std::env::var("CTAYLOR_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    println!("{}", ctaylor::bench::run_fig5_table1(&reg, reps)?);
    Ok(())
}
