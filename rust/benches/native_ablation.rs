//! Native-engine ablation: nested vs standard vs collapsed on the in-Rust
//! engines plus the §C graph-rewrite effect.  `cargo bench --bench native_ablation`.
fn main() -> anyhow::Result<()> {
    let reps = std::env::var("CTAYLOR_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    println!("{}", ctaylor::bench::run_native_ablation(reps)?);
    Ok(())
}
