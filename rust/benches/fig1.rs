//! Regenerates paper Fig. 1: exact-Laplacian runtime vs batch, three
//! implementations.  `cargo bench --bench fig1`.
fn main() -> anyhow::Result<()> {
    let reg = ctaylor::runtime::Registry::load_default()?;
    let reps = std::env::var("CTAYLOR_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    println!("{}", ctaylor::bench::run_fig1(&reg, reps)?);
    Ok(())
}
